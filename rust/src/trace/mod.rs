//! Deterministic per-request tracing: virtual-clock span timelines plus
//! causal annotations, recorded by the fleet simulator and exported as
//! JSONL or Chrome `trace_event` JSON (see [`export`] and
//! `simulate --trace-out`).
//!
//! A sampled request's life is a gapless tiling of [`Span`]s — device
//! queue wait, head compute, radio uplink, edge torso queue + service,
//! backhaul relay, cloud queue + service, and the zero-length downlink
//! the paper's Eq. 14 excludes — every timestamp taken from the sim's
//! virtual clock with the *exact* f64 arithmetic the engine scheduled
//! with, so span boundaries chain bit-for-bit
//! (`tests/observability.rs` pins the tiling). Causal annotations
//! ([`CausalEvent`]) record the *why* alongside the *when*: every
//! re-plan with its [`ReplanReason`] and façade provenance, every
//! handover torso-state relay, every re-attachment.
//!
//! Determinism contract: the recorder keys open traces in a `BTreeMap`
//! (ordered, hasher-free — detlint rule D3 bans default-hasher maps on
//! the export plane, so the ordering guarantee is structural, not a
//! comment) — completed traces land in a `Vec` in completion order and
//! annotations in record order, so two runs of a frozen scenario
//! export byte-identical files regardless of thread configuration.
//! `tests/export_order.rs` pins this: shuffled insertion orders export
//! byte-identically across 100 reruns. Recording is opt-in per request
//! via the sampling knob (`sample_every`); unsampled requests cost one
//! modulo per hook.

pub mod export;

use std::collections::BTreeMap;

use crate::planner::{CacheOutcome, ReplanReason, Strategy};

/// One stage of a request's path through the three-tier pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Waiting in the device's FIFO backlog (zero-length when idle).
    DeviceQueue,
    /// Head layers `1..=l1` on the device NPU/CPU.
    HeadCompute,
    /// Radio upload of the layer-`l1` activation.
    Uplink,
    /// Waiting for a free torso server at the edge site.
    EdgeQueue,
    /// Torso layers `l1+1..=l2` on the edge site.
    EdgeService,
    /// Edge→cloud relay of the layer-`l2` activation.
    Backhaul,
    /// Waiting for a free cloud server.
    CloudQueue,
    /// Tail layers `l2+1..=L` in the cloud.
    CloudService,
    /// Result download — zero-length by the paper's Eq. 14 (the
    /// classification result is negligibly small).
    Downlink,
}

impl SpanKind {
    /// Stable export name (the JSONL / Chrome-trace event name).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::DeviceQueue => "device_queue",
            SpanKind::HeadCompute => "head_compute",
            SpanKind::Uplink => "uplink",
            SpanKind::EdgeQueue => "edge_queue",
            SpanKind::EdgeService => "edge_service",
            SpanKind::Backhaul => "backhaul",
            SpanKind::CloudQueue => "cloud_queue",
            SpanKind::CloudService => "cloud_service",
            SpanKind::Downlink => "downlink",
        }
    }
}

/// One virtual-time interval of a request's timeline. `site` is the
/// edge-site index for edge/backhaul spans and the cloud index for
/// cloud spans; `None` for device-local stages.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub start_s: f64,
    pub end_s: f64,
    pub site: Option<u32>,
}

impl Span {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// The complete recorded timeline of one sampled request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    /// Fleet-wide request id (issue order).
    pub req: u64,
    /// Device the request ran on.
    pub device: u64,
    /// Virtual time the request was issued (span tiling starts here).
    pub issued_s: f64,
    /// Virtual completion time (the tiling ends here; `NaN` while the
    /// request is still in flight).
    pub completed_s: f64,
    /// Gapless, ordered stage intervals covering
    /// `[issued_s, completed_s]`.
    pub spans: Vec<Span>,
}

impl RequestTrace {
    /// Recorded end-to-end latency.
    pub fn latency_s(&self) -> f64 {
        self.completed_s - self.issued_s
    }
}

/// A causally significant moment recorded alongside the span
/// timelines: why plans changed and what mobility did, each tagged
/// with the provenance the planner façade already produces.
#[derive(Clone, Debug, PartialEq)]
pub enum CausalEvent {
    /// A split decision was adopted (spawn, drift sweep, battery-band
    /// crossing, or migration), with the [`crate::planner::Provenance`]
    /// fields that make the solve reproducible offline.
    Replan {
        t_s: f64,
        device: u64,
        reason: ReplanReason,
        strategy: Strategy,
        cache: CacheOutcome,
        /// Adopted `(l1, l2)`; `None` when the strategy found no
        /// feasible split.
        plan: Option<(u32, u32)>,
        quantized_bw_mbps: f64,
        derived_seed: u64,
    },
    /// An edge handover's torso-state relay: the control-plane cost
    /// plus the state transfer over the *old* site's backhaul.
    HandoverRelay {
        start_s: f64,
        end_s: f64,
        device: u64,
        from_site: u32,
        to_site: u32,
        state_bytes: u64,
    },
    /// The device finished re-attaching to its new site; `replanned`
    /// says whether a migration re-solve was adopted.
    Reattach { t_s: f64, device: u64, site: u32, replanned: bool },
    /// An injected fault edge ([`crate::sim::faults`]) was applied:
    /// `kind` is the stable edge name (`site_down`, `site_up`,
    /// `backhaul_degrade`, `backhaul_restore`, `flash_crowd_start`,
    /// `flash_crowd_end`), `value` its scalar argument (degrade factor,
    /// arrival boost; 0 where meaningless).
    Fault { t_s: f64, kind: &'static str, site: u32, value: f64 },
    /// A site outage forced request `req` (in flight or queued at the
    /// dead site) to be relayed onward to the cloud — the conservation
    /// path: rerouted, never lost.
    Failover { t_s: f64, req: u64, device: u64, from_site: u32 },
}

impl CausalEvent {
    /// Stable export name.
    pub fn name(&self) -> &'static str {
        match self {
            CausalEvent::Replan { .. } => "replan",
            CausalEvent::HandoverRelay { .. } => "handover_relay",
            CausalEvent::Reattach { .. } => "reattach",
            CausalEvent::Fault { .. } => "fault",
            CausalEvent::Failover { .. } => "failover",
        }
    }

    /// Virtual time of the annotation (start time for intervals).
    pub fn t_s(&self) -> f64 {
        match self {
            CausalEvent::Replan { t_s, .. } => *t_s,
            CausalEvent::HandoverRelay { start_s, .. } => *start_s,
            CausalEvent::Reattach { t_s, .. } => *t_s,
            CausalEvent::Fault { t_s, .. } => *t_s,
            CausalEvent::Failover { t_s, .. } => *t_s,
        }
    }
}

/// Export name of a [`CacheOutcome`] (the planner enum itself stays
/// presentation-free).
pub fn cache_outcome_name(c: CacheOutcome) -> &'static str {
    match c {
        CacheOutcome::Hit => "hit",
        CacheOutcome::Miss => "miss",
        CacheOutcome::Bypassed => "bypass",
    }
}

/// The in-run recorder: open traces keyed by request id, completed
/// traces in completion order, annotations in record order.
///
/// Span hooks silently no-op for unsampled requests, so the sim wires
/// them unconditionally. The map is a `BTreeMap`, so even an iteration
/// added later would be deterministic (see the module docs).
#[derive(Debug)]
pub struct TraceRecorder {
    sample_every: u64,
    open: BTreeMap<u64, RequestTrace>,
    done: Vec<RequestTrace>,
    events: Vec<CausalEvent>,
}

impl TraceRecorder {
    /// Record every `sample_every`-th request (1 = all). Annotations
    /// are always recorded — they are per-device, not per-request.
    pub fn new(sample_every: u64) -> TraceRecorder {
        assert!(sample_every >= 1, "sample_every must be >= 1");
        TraceRecorder {
            sample_every,
            open: BTreeMap::new(),
            done: Vec::new(),
            events: Vec::new(),
        }
    }

    /// Is request `req` in the recorded sample?
    pub fn sampled(&self, req: u64) -> bool {
        req % self.sample_every == 0
    }

    /// Open a timeline for `req` (no-op when unsampled).
    pub fn begin(&mut self, req: u64, device: u64, issued_s: f64) {
        if !self.sampled(req) {
            return;
        }
        self.open.insert(
            req,
            RequestTrace { req, device, issued_s, completed_s: f64::NAN, spans: Vec::new() },
        );
    }

    /// Append a closed span to `req`'s timeline.
    pub fn span(&mut self, req: u64, kind: SpanKind, start_s: f64, end_s: f64, site: Option<u32>) {
        if !self.sampled(req) {
            return;
        }
        if let Some(t) = self.open.get_mut(&req) {
            t.spans.push(Span { kind, start_s, end_s, site });
        }
    }

    /// Open a span whose end is not yet known (a queue wait of unknown
    /// length); close it with [`TraceRecorder::end_span`].
    pub fn begin_span(&mut self, req: u64, kind: SpanKind, start_s: f64, site: Option<u32>) {
        self.span(req, kind, start_s, f64::NAN, site);
    }

    /// Close `req`'s most recent open span.
    pub fn end_span(&mut self, req: u64, end_s: f64) {
        if !self.sampled(req) {
            return;
        }
        if let Some(t) = self.open.get_mut(&req) {
            if let Some(s) = t.spans.last_mut() {
                debug_assert!(s.end_s.is_nan(), "end_span on a closed {:?} span", s.kind);
                s.end_s = end_s;
            }
        }
    }

    /// Complete `req`: stamp the completion time, append the
    /// zero-length downlink span, and move the trace to the completed
    /// list (completion order = export order).
    pub fn complete(&mut self, req: u64, completed_s: f64) {
        if !self.sampled(req) {
            return;
        }
        if let Some(mut t) = self.open.remove(&req) {
            t.completed_s = completed_s;
            t.spans.push(Span {
                kind: SpanKind::Downlink,
                start_s: completed_s,
                end_s: completed_s,
                site: None,
            });
            self.done.push(t);
        }
    }

    /// Record a causal annotation (always; annotations are not
    /// subject to request sampling).
    pub fn note(&mut self, event: CausalEvent) {
        self.events.push(event);
    }

    /// Seal the recorder into its exportable report.
    pub fn finish(self) -> TraceReport {
        TraceReport {
            sample_every: self.sample_every,
            unfinished: self.open.len() as u64,
            requests: self.done,
            events: self.events,
        }
    }
}

/// The sealed result of a traced run, carried in
/// [`crate::sim::SimReport`] and exported by [`export`].
#[derive(Clone, Debug, PartialEq)]
pub struct TraceReport {
    /// The sampling knob the run recorded under.
    pub sample_every: u64,
    /// Sampled requests still open when the run ended (0 when the
    /// event queue drained — pinned by `tests/observability.rs`).
    pub unfinished: u64,
    /// Completed timelines, in completion order.
    pub requests: Vec<RequestTrace>,
    /// Causal annotations, in record order.
    pub events: Vec<CausalEvent>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_one(rec: &mut TraceRecorder, req: u64, device: u64, t0: f64) {
        rec.begin(req, device, t0);
        rec.span(req, SpanKind::DeviceQueue, t0, t0, None);
        rec.span(req, SpanKind::HeadCompute, t0, t0 + 0.2, None);
        rec.span(req, SpanKind::Uplink, t0 + 0.2, t0 + 0.5, None);
        rec.begin_span(req, SpanKind::CloudQueue, t0 + 0.5, Some(0));
        rec.end_span(req, t0 + 0.7);
        rec.span(req, SpanKind::CloudService, t0 + 0.7, t0 + 1.0, Some(0));
        rec.complete(req, t0 + 1.0);
    }

    #[test]
    fn timeline_tiles_from_issue_to_completion() {
        let mut rec = TraceRecorder::new(1);
        record_one(&mut rec, 0, 7, 10.0);
        let rep = rec.finish();
        assert_eq!(rep.unfinished, 0);
        assert_eq!(rep.requests.len(), 1);
        let t = &rep.requests[0];
        assert_eq!((t.req, t.device), (0, 7));
        assert_eq!(t.spans.first().unwrap().start_s, t.issued_s);
        assert_eq!(t.spans.last().unwrap().end_s, t.completed_s);
        assert_eq!(t.spans.last().unwrap().kind, SpanKind::Downlink);
        for w in t.spans.windows(2) {
            assert_eq!(w[0].end_s, w[1].start_s, "gap between {:?} and {:?}", w[0], w[1]);
        }
        assert!((t.latency_s() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_skips_off_sample_requests_silently() {
        let mut rec = TraceRecorder::new(2);
        assert!(rec.sampled(0) && !rec.sampled(1) && rec.sampled(2));
        record_one(&mut rec, 0, 1, 0.0);
        record_one(&mut rec, 1, 1, 5.0); // every hook must no-op
        record_one(&mut rec, 2, 2, 9.0);
        let rep = rec.finish();
        assert_eq!(rep.requests.len(), 2);
        assert_eq!(rep.requests[0].req, 0);
        assert_eq!(rep.requests[1].req, 2);
    }

    #[test]
    fn completion_order_is_export_order() {
        let mut rec = TraceRecorder::new(1);
        rec.begin(0, 0, 0.0);
        rec.begin(1, 1, 0.5);
        // Request 1 completes before request 0.
        rec.span(1, SpanKind::HeadCompute, 0.5, 1.0, None);
        rec.complete(1, 1.0);
        rec.span(0, SpanKind::HeadCompute, 0.0, 2.0, None);
        rec.complete(0, 2.0);
        let rep = rec.finish();
        let order: Vec<u64> = rep.requests.iter().map(|t| t.req).collect();
        assert_eq!(order, vec![1, 0]);
    }

    #[test]
    fn unfinished_counts_open_traces() {
        let mut rec = TraceRecorder::new(1);
        rec.begin(0, 0, 0.0);
        rec.begin(1, 1, 0.0);
        rec.complete(1, 3.0);
        let rep = rec.finish();
        assert_eq!(rep.unfinished, 1);
        assert_eq!(rep.requests.len(), 1);
    }

    #[test]
    fn annotations_keep_record_order_and_names() {
        let mut rec = TraceRecorder::new(1);
        rec.note(CausalEvent::Replan {
            t_s: 1.0,
            device: 3,
            reason: ReplanReason::Spawn,
            strategy: Strategy::Topsis,
            cache: CacheOutcome::Miss,
            plan: Some((2, 5)),
            quantized_bw_mbps: 10.0,
            derived_seed: 42,
        });
        rec.note(CausalEvent::HandoverRelay {
            start_s: 2.0,
            end_s: 2.1,
            device: 3,
            from_site: 0,
            to_site: 1,
            state_bytes: 4096,
        });
        rec.note(CausalEvent::Reattach { t_s: 2.1, device: 3, site: 1, replanned: true });
        let rep = rec.finish();
        let names: Vec<&str> = rep.events.iter().map(|e| e.name()).collect();
        assert_eq!(names, vec!["replan", "handover_relay", "reattach"]);
        assert_eq!(rep.events[0].t_s(), 1.0);
        assert_eq!(rep.events[1].t_s(), 2.0);
        assert_eq!(cache_outcome_name(CacheOutcome::Bypassed), "bypass");
    }
}
