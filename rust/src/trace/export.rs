//! Machine-readable exports of a [`TraceReport`]: line-delimited JSON
//! (one self-describing object per line — a meta header, then one line
//! per completed request in completion order, then the causal
//! annotations in record order) and Chrome `trace_event` JSON
//! (loadable in `chrome://tracing` / Perfetto: spans as `"X"` complete
//! events on a per-device track, annotations as `"i"` instants).
//!
//! Both formats are built from [`crate::util::json::Json`] values with
//! insertion-ordered keys and serialized compactly, so a frozen
//! scenario exports byte-identical files on every run — the property
//! `tests/observability.rs` asserts. 64-bit solve seeds are exported
//! as hex strings (a JSON number would round through f64 and lose low
//! bits).

use std::io;
use std::path::Path;

use crate::util::json::Json;

use super::{cache_outcome_name, CausalEvent, RequestTrace, Span, TraceReport};

/// Schema version stamped into the JSONL meta header and the Chrome
/// export's `otherData`. History: 1 = PR 6/PR 7 (`"version"` key);
/// 2 = the key is named `schema_version` and fault/failover lines are
/// part of the contract. Readers ([`crate::analyze`],
/// `.github/check_observability.py`) accept both spellings.
pub const TRACE_SCHEMA_VERSION: u64 = 2;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn count(x: u64) -> Json {
    Json::Num(x as f64)
}

fn span_json(s: &Span) -> Json {
    let mut pairs = vec![
        ("kind", Json::str(s.kind.name())),
        ("start_s", num(s.start_s)),
        ("end_s", num(s.end_s)),
    ];
    if let Some(site) = s.site {
        pairs.push(("site", count(site as u64)));
    }
    Json::obj(pairs)
}

fn request_json(t: &RequestTrace) -> Json {
    Json::obj(vec![
        ("type", Json::str("request")),
        ("req", count(t.req)),
        ("device", count(t.device)),
        ("issued_s", num(t.issued_s)),
        ("completed_s", num(t.completed_s)),
        ("latency_s", num(t.latency_s())),
        ("spans", Json::Arr(t.spans.iter().map(span_json).collect())),
    ])
}

fn event_json(e: &CausalEvent) -> Json {
    match *e {
        CausalEvent::Replan {
            t_s,
            device,
            reason,
            strategy,
            cache,
            plan,
            quantized_bw_mbps,
            derived_seed,
        } => Json::obj(vec![
            ("type", Json::str("replan")),
            ("t_s", num(t_s)),
            ("device", count(device)),
            ("reason", Json::str(reason.name())),
            ("strategy", Json::str(strategy.name())),
            ("cache", Json::str(cache_outcome_name(cache))),
            (
                "plan",
                match plan {
                    Some((l1, l2)) => Json::obj(vec![
                        ("l1", count(l1 as u64)),
                        ("l2", count(l2 as u64)),
                    ]),
                    None => Json::Null,
                },
            ),
            ("quantized_bw_mbps", num(quantized_bw_mbps)),
            ("derived_seed", Json::str(&format!("{derived_seed:#018x}"))),
        ]),
        CausalEvent::HandoverRelay { start_s, end_s, device, from_site, to_site, state_bytes } => {
            Json::obj(vec![
                ("type", Json::str("handover_relay")),
                ("start_s", num(start_s)),
                ("end_s", num(end_s)),
                ("device", count(device)),
                ("from_site", count(from_site as u64)),
                ("to_site", count(to_site as u64)),
                ("state_bytes", count(state_bytes)),
            ])
        }
        CausalEvent::Reattach { t_s, device, site, replanned } => Json::obj(vec![
            ("type", Json::str("reattach")),
            ("t_s", num(t_s)),
            ("device", count(device)),
            ("site", count(site as u64)),
            ("replanned", Json::Bool(replanned)),
        ]),
        CausalEvent::Fault { t_s, kind, site, value } => Json::obj(vec![
            ("type", Json::str("fault")),
            ("t_s", num(t_s)),
            ("kind", Json::str(kind)),
            ("site", count(site as u64)),
            ("value", num(value)),
        ]),
        CausalEvent::Failover { t_s, req, device, from_site } => Json::obj(vec![
            ("type", Json::str("failover")),
            ("t_s", num(t_s)),
            ("req", count(req)),
            ("device", count(device)),
            ("from_site", count(from_site as u64)),
        ]),
    }
}

const MICROS: f64 = 1e6;

fn chrome_span(t: &RequestTrace, s: &Span) -> Json {
    let mut args = vec![("req", count(t.req))];
    if let Some(site) = s.site {
        args.push(("site", count(site as u64)));
    }
    Json::obj(vec![
        ("name", Json::str(s.kind.name())),
        ("cat", Json::str("request")),
        ("ph", Json::str("X")),
        ("ts", num(s.start_s * MICROS)),
        ("dur", num(s.duration_s() * MICROS)),
        ("pid", count(0)),
        ("tid", count(t.device)),
        ("args", Json::obj(args)),
    ])
}

fn chrome_instant(e: &CausalEvent) -> Json {
    let device = match *e {
        CausalEvent::Replan { device, .. }
        | CausalEvent::HandoverRelay { device, .. }
        | CausalEvent::Reattach { device, .. }
        | CausalEvent::Failover { device, .. } => device,
        // Faults are site-scoped, not device-scoped: park them on a
        // dedicated track keyed far above any real device id.
        CausalEvent::Fault { site, .. } => u64::MAX - site as u64,
    };
    Json::obj(vec![
        ("name", Json::str(e.name())),
        ("cat", Json::str("causal")),
        ("ph", Json::str("i")),
        ("ts", num(e.t_s() * MICROS)),
        ("pid", count(0)),
        ("tid", count(device)),
        ("s", Json::str("t")),
        ("args", event_json(e)),
    ])
}

impl TraceReport {
    /// Header object of the JSONL export (also embedded in the Chrome
    /// export's `otherData`).
    fn meta_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::str("meta")),
            ("format", Json::str("smartsplit-trace")),
            ("schema_version", count(TRACE_SCHEMA_VERSION)),
            ("sample_every", count(self.sample_every)),
            ("requests", count(self.requests.len() as u64)),
            ("events", count(self.events.len() as u64)),
            ("unfinished", count(self.unfinished)),
        ])
    }

    /// Line-delimited JSON: meta header, completed requests in
    /// completion order, then causal annotations in record order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.meta_json().to_string());
        out.push('\n');
        for t in &self.requests {
            out.push_str(&request_json(t).to_string());
            out.push('\n');
        }
        for e in &self.events {
            out.push_str(&event_json(e).to_string());
            out.push('\n');
        }
        out
    }

    /// Chrome `trace_event` JSON (object form): spans as `"X"`
    /// complete events with microsecond timestamps on track
    /// `pid 0 / tid <device>`, annotations as thread-scoped `"i"`
    /// instants.
    pub fn to_chrome_trace(&self) -> String {
        let mut events = Vec::new();
        for t in &self.requests {
            for s in &t.spans {
                events.push(chrome_span(t, s));
            }
        }
        for e in &self.events {
            events.push(chrome_instant(e));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("otherData", self.meta_json()),
        ])
        .to_string()
    }

    /// Write the export `path`'s extension selects: `.jsonl` → JSONL,
    /// anything else (conventionally `.json`) → Chrome `trace_event`.
    pub fn export(&self, path: &Path) -> io::Result<()> {
        let body = match path.extension().and_then(|e| e.to_str()) {
            Some("jsonl") => self.to_jsonl(),
            _ => self.to_chrome_trace(),
        };
        std::fs::write(path, body)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{SpanKind, TraceRecorder};
    use super::*;
    use crate::planner::{CacheOutcome, ReplanReason, Strategy};

    fn sample_report() -> TraceReport {
        let mut rec = TraceRecorder::new(1);
        rec.note(CausalEvent::Replan {
            t_s: 0.0,
            device: 4,
            reason: ReplanReason::Spawn,
            strategy: Strategy::Topsis,
            cache: CacheOutcome::Miss,
            plan: Some((2, 2)),
            quantized_bw_mbps: 12.5,
            derived_seed: u64::MAX,
        });
        rec.begin(0, 4, 1.0);
        rec.span(0, SpanKind::DeviceQueue, 1.0, 1.0, None);
        rec.span(0, SpanKind::HeadCompute, 1.0, 1.25, None);
        rec.span(0, SpanKind::Uplink, 1.25, 1.5, None);
        rec.span(0, SpanKind::EdgeQueue, 1.5, 1.5, Some(2));
        rec.span(0, SpanKind::EdgeService, 1.5, 1.75, Some(2));
        rec.complete(0, 1.75);
        rec.note(CausalEvent::HandoverRelay {
            start_s: 2.0,
            end_s: 2.25,
            device: 4,
            from_site: 2,
            to_site: 0,
            state_bytes: 1 << 20,
        });
        rec.finish()
    }

    #[test]
    fn jsonl_lines_are_self_describing_and_parseable() {
        let rep = sample_report();
        let text = rep.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        // meta + 1 request + 2 events.
        assert_eq!(lines.len(), 4);
        let meta = Json::parse(lines[0]).expect("meta parses");
        assert_eq!(meta.get_str("type").unwrap(), "meta");
        assert_eq!(meta.get_usize("schema_version").unwrap(), TRACE_SCHEMA_VERSION as usize);
        assert_eq!(meta.get_usize("requests").unwrap(), 1);
        assert_eq!(meta.get_usize("events").unwrap(), 2);
        assert_eq!(meta.get_usize("unfinished").unwrap(), 0);

        let req = Json::parse(lines[1]).expect("request parses");
        assert_eq!(req.get_str("type").unwrap(), "request");
        let spans = req.get("spans").unwrap().as_arr().unwrap();
        // 5 recorded + appended downlink.
        assert_eq!(spans.len(), 6);
        assert_eq!(spans[3].get_str("kind").unwrap(), "edge_queue");
        assert_eq!(spans[3].get_usize("site").unwrap(), 2);
        assert_eq!(req.get_f64("latency_s").unwrap(), 0.75);

        let replan = Json::parse(lines[2]).expect("replan parses");
        assert_eq!(replan.get_str("type").unwrap(), "replan");
        assert_eq!(replan.get_str("reason").unwrap(), "spawn");
        assert_eq!(replan.get("plan").unwrap().get_usize("l2").unwrap(), 2);
        // Full-width seeds survive as hex strings.
        assert_eq!(replan.get_str("derived_seed").unwrap(), "0xffffffffffffffff");

        let relay = Json::parse(lines[3]).expect("relay parses");
        assert_eq!(relay.get_str("type").unwrap(), "handover_relay");
        assert_eq!(relay.get_usize("state_bytes").unwrap(), 1 << 20);
    }

    #[test]
    fn chrome_trace_parses_with_microsecond_timestamps() {
        let rep = sample_report();
        let doc = Json::parse(&rep.to_chrome_trace()).expect("chrome trace parses");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 6 spans + 2 instants.
        assert_eq!(events.len(), 8);
        let head = &events[1];
        assert_eq!(head.get_str("ph").unwrap(), "X");
        assert_eq!(head.get_str("name").unwrap(), "head_compute");
        assert_eq!(head.get_f64("ts").unwrap(), 1.0 * 1e6);
        assert_eq!(head.get_f64("dur").unwrap(), 0.25 * 1e6);
        assert_eq!(head.get_usize("tid").unwrap(), 4);
        let instant = &events[6];
        assert_eq!(instant.get_str("ph").unwrap(), "i");
        assert_eq!(instant.get_str("name").unwrap(), "replan");
        assert_eq!(
            instant.get("args").unwrap().get_str("strategy").unwrap(),
            "Topsis"
        );
        assert_eq!(doc.get("otherData").unwrap().get_str("format").unwrap(), "smartsplit-trace");
    }

    #[test]
    fn fault_and_failover_events_export_with_t_s_and_type() {
        let mut rec = TraceRecorder::new(1);
        rec.note(CausalEvent::Fault { t_s: 30.0, kind: "site_down", site: 1, value: 0.0 });
        rec.note(CausalEvent::Failover { t_s: 30.0, req: 17, device: 4, from_site: 1 });
        rec.note(CausalEvent::Fault {
            t_s: 45.0,
            kind: "backhaul_degrade",
            site: 0,
            value: 0.25,
        });
        let rep = rec.finish();
        let lines: Vec<&str> = rep.to_jsonl().lines().skip(1).map(str::trim).collect();
        let fault = Json::parse(lines[0]).expect("fault parses");
        assert_eq!(fault.get_str("type").unwrap(), "fault");
        assert_eq!(fault.get_str("kind").unwrap(), "site_down");
        assert_eq!(fault.get_f64("t_s").unwrap(), 30.0);
        assert_eq!(fault.get_usize("site").unwrap(), 1);
        let failover = Json::parse(lines[1]).expect("failover parses");
        assert_eq!(failover.get_str("type").unwrap(), "failover");
        assert_eq!(failover.get_usize("req").unwrap(), 17);
        assert_eq!(failover.get_usize("from_site").unwrap(), 1);
        let brown = Json::parse(lines[2]).expect("brownout parses");
        assert_eq!(brown.get_f64("value").unwrap(), 0.25);
        // Chrome export: failovers ride their device's track, faults a
        // dedicated per-site track.
        let doc = Json::parse(&rep.to_chrome_trace()).expect("chrome parses");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(events[1].get_str("name").unwrap(), "failover");
        assert_eq!(events[1].get_usize("tid").unwrap(), 4);
    }

    #[test]
    fn export_is_deterministic_across_calls() {
        let a = sample_report();
        let b = sample_report();
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        assert_eq!(a.to_chrome_trace(), b.to_chrome_trace());
    }
}
