//! The paper's latency and energy models (§III, Eq. 2–13) and the three
//! objective functions (§IV, Eq. 14–16).
//!
//! Unit conventions (the paper leaves units implicit; we fix them and
//! calibrate one constant, documented in DESIGN.md §4):
//!
//! * memory quantities `M|l1`, `I|l1` — **bytes** (ref [39] accounting,
//!   computed by [`crate::models::ModelProfile`]);
//! * processor speed `S` — **Hz**; operating frequency `ν` — **GHz**
//!   (as in Eq. 6, where the paper's fitted `k = 1.172` assumes GHz);
//! * bandwidth `B` and throughputs `τ_u`, `τ_d` — **Mbps**;
//! * power — **Watts** internally (radio constants are mW in the paper and
//!   converted here); energy — **Joules**; latency — **seconds**.
//!
//! The paper's `T_client = M|l1 / (C·S)` implicitly assumes one byte
//! processed per core-cycle. Real PyTorch-Mobile inference costs tens of
//! cycles per byte touched, so each compute profile carries a calibrated
//! `cycles_per_byte` factor (J6/Redmi ≈ 25, cloud server ≈ 6); this is a
//! pure time-scale calibration that cancels in every paper comparison.

use crate::device::ComputeProfile;
use crate::models::ModelProfile;

/// Radio power model (Huang et al. [41]): `P = α·τ + β`, α in mW/Mbps and
/// β in mW.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RadioPower {
    pub alpha_up_mw_per_mbps: f64,
    pub beta_up_mw: f64,
    pub alpha_down_mw_per_mbps: f64,
    pub beta_down_mw: f64,
}

impl RadioPower {
    /// The paper's constants (§III-C), fitted for 802.11 b/g/n-class radios.
    pub const PAPER_80211N: RadioPower = RadioPower {
        alpha_up_mw_per_mbps: 283.17,
        beta_up_mw: 132.86,
        alpha_down_mw_per_mbps: 137.01,
        beta_down_mw: 132.86,
    };

    /// 802.11ac-class radio: substantially more energy-efficient per Mbps
    /// (Sun et al. [37], Noordbruis et al. [38]); calibrated so Fig. 4
    /// reproduces the paper's client-energy-dominates shape on Redmi Note 8.
    pub const WIFI_80211AC: RadioPower = RadioPower {
        alpha_up_mw_per_mbps: 70.0,
        beta_up_mw: 110.0,
        alpha_down_mw_per_mbps: 50.0,
        beta_down_mw: 110.0,
    };

    /// Upload power in **Watts** at throughput `tau_mbps` (Eq. 8).
    pub fn upload_power_w(&self, tau_mbps: f64) -> f64 {
        (self.alpha_up_mw_per_mbps * tau_mbps + self.beta_up_mw) / 1000.0
    }

    /// Download power in **Watts** at throughput `tau_mbps` (Eq. 10).
    pub fn download_power_w(&self, tau_mbps: f64) -> f64 {
        (self.alpha_down_mw_per_mbps * tau_mbps + self.beta_down_mw) / 1000.0
    }
}

/// The paper's fitted dynamic-power constant (Eq. 6): `P = k·C·ν³`,
/// ν in GHz, P in Watts.
pub const K_CLIENT_POWER: f64 = 1.172;

/// Network conditions for one evaluation.
#[derive(Clone, Copy, Debug)]
pub struct NetworkEnv {
    /// Link bandwidth `B` in Mbps (paper testbed: 10).
    pub bandwidth_mbps: f64,
    /// Upload throughput `τ_u` in Mbps; constraint `τ_u ≤ B`.
    pub tau_up_mbps: f64,
    /// Download throughput `τ_d` in Mbps; constraint `τ_d ≤ B`.
    pub tau_down_mbps: f64,
}

impl NetworkEnv {
    /// Paper testbed: 10 Mbps WiFi, saturating transfers.
    pub fn paper_default() -> Self {
        NetworkEnv { bandwidth_mbps: 10.0, tau_up_mbps: 10.0, tau_down_mbps: 10.0 }
    }

    pub fn with_bandwidth(mbps: f64) -> Self {
        NetworkEnv { bandwidth_mbps: mbps, tau_up_mbps: mbps, tau_down_mbps: mbps }
    }

    pub fn satisfies_constraints(&self) -> bool {
        self.tau_up_mbps <= self.bandwidth_mbps && self.tau_down_mbps <= self.bandwidth_mbps
    }
}

/// Full evaluation context: phone + cloud + network + model.
#[derive(Clone, Debug)]
pub struct PerfModel<'a> {
    pub client: &'a ComputeProfile,
    pub server: &'a ComputeProfile,
    pub radio: RadioPower,
    pub net: NetworkEnv,
    pub profile: &'a ModelProfile,
    /// Result download size `d` in bytes (logits; ~4 KB, negligible — as
    /// the paper observes for download latency).
    pub download_bytes: u64,
}

/// Component breakdown of Eq. 5 (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    pub client_s: f64,
    pub upload_s: f64,
    pub server_s: f64,
    pub download_s: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        // Download latency is measured but excluded from the paper's totals
        // ("we observe that the Download Latency is negligible and hence is
        // not included in our results", §III-A1).
        self.client_s + self.upload_s + self.server_s
    }
}

/// Component breakdown of Eq. 13 (Joules).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub client_j: f64,
    pub upload_j: f64,
    pub download_j: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.client_j + self.upload_j + self.download_j
    }
}

impl<'a> PerfModel<'a> {
    pub fn new(
        client: &'a ComputeProfile,
        server: &'a ComputeProfile,
        radio: RadioPower,
        net: NetworkEnv,
        profile: &'a ModelProfile,
    ) -> Self {
        let download_bytes =
            profile.layers.last().map(|l| l.act_bytes).unwrap_or(4000);
        PerfModel { client, server, radio, net, profile, download_bytes }
    }

    // ------------------------------------------------------------- latency

    /// Eq. 2: `T_client = M_client|l1 · cpb / (C·S)`.
    pub fn client_latency_s(&self, l1: usize) -> f64 {
        let m = self.profile.client_memory_bytes(l1) as f64;
        m * self.client.cycles_per_byte
            / (self.client.cores as f64 * self.client.clock_hz)
    }

    /// Eq. 3: `T_server = M_server|l2 · cpb / (C·S)`.
    pub fn server_latency_s(&self, l1: usize) -> f64 {
        let m = self.profile.server_memory_bytes(l1) as f64;
        m * self.server.cycles_per_byte
            / (self.server.cores as f64 * self.server.clock_hz)
    }

    /// Eq. 4: `T_upload = I|l1 / B` (bits over Mbps).
    pub fn upload_latency_s(&self, l1: usize) -> f64 {
        if l1 >= self.profile.num_layers {
            return 0.0; // COS: nothing shipped
        }
        let bits = self.profile.intermediate_bytes(l1) as f64 * 8.0;
        bits / (self.net.bandwidth_mbps * 1e6)
    }

    /// Eq. 11: `T_download = d / B`.
    pub fn download_latency_s(&self, l1: usize) -> f64 {
        if l1 >= self.profile.num_layers {
            return 0.0; // COS: result already on device
        }
        self.download_bytes as f64 * 8.0 / (self.net.bandwidth_mbps * 1e6)
    }

    /// Eq. 5 breakdown at split `l1` (layers 1..=l1 on the phone).
    /// `l1 = 0` is COC (all cloud: the raw input is the "intermediate"),
    /// `l1 = L` is COS (all phone).
    pub fn latency(&self, l1: usize) -> LatencyBreakdown {
        if l1 == 0 {
            // COC: upload the input image instead of an activation.
            let input_bytes = self.profile.input_bytes();
            return LatencyBreakdown {
                client_s: 0.0,
                upload_s: input_bytes as f64 * 8.0 / (self.net.bandwidth_mbps * 1e6),
                server_s: self.server_latency_s(0),
                download_s: self.download_bytes as f64 * 8.0
                    / (self.net.bandwidth_mbps * 1e6),
            };
        }
        LatencyBreakdown {
            client_s: self.client_latency_s(l1),
            upload_s: self.upload_latency_s(l1),
            server_s: self.server_latency_s(l1),
            download_s: self.download_latency_s(l1),
        }
    }

    // -------------------------------------------------------------- energy

    /// Eq. 6: client dynamic power in Watts.
    pub fn client_power_w(&self) -> f64 {
        K_CLIENT_POWER * self.client.cores as f64 * self.client.freq_ghz.powi(3)
    }

    /// Eq. 13 breakdown at split `l1`.
    pub fn energy(&self, l1: usize) -> EnergyBreakdown {
        let lat = self.latency(l1);
        let client_j = self.client_power_w() * lat.client_s;
        let upload_j = self.radio.upload_power_w(self.net.tau_up_mbps) * lat.upload_s;
        let download_j =
            self.radio.download_power_w(self.net.tau_down_mbps) * lat.download_s;
        EnergyBreakdown { client_j, upload_j, download_j }
    }

    // ---------------------------------------------------------- objectives

    /// Eq. 14: `f1(l1, l2)` — end-to-end latency (seconds).
    pub fn f1(&self, l1: usize) -> f64 {
        self.latency(l1).total()
    }

    /// Eq. 15: `f2(l1)` — smartphone energy (Joules).
    pub fn f2(&self, l1: usize) -> f64 {
        self.energy(l1).total()
    }

    /// Eq. 16: `f3(l1)` — smartphone memory (bytes).
    pub fn f3(&self, l1: usize) -> f64 {
        self.profile.client_memory_bytes(l1) as f64
    }

    /// All three objectives at once (the optimiser's evaluation).
    pub fn objectives(&self, l1: usize) -> [f64; 3] {
        [self.f1(l1), self.f2(l1), self.f3(l1)]
    }

    /// Eq. 17 constraints for a candidate split.
    pub fn feasible(&self, l1: usize) -> bool {
        let l = self.profile.num_layers;
        // 1 ≤ l1, l2 ≤ L with l1 + l2 = L  ⇒  1 ≤ l1 ≤ L-1
        if l1 < 1 || l1 + 1 > l {
            return false;
        }
        // M_edge|l1 ≤ M (client memory capacity)
        if self.profile.client_memory_bytes(l1) > self.client.memory_bytes {
            return false;
        }
        // τ_u ≤ B, τ_d ≤ B
        self.net.satisfies_constraints()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::models::zoo;

    fn model() -> crate::models::ModelProfile {
        zoo::alexnet().analyze(1)
    }

    fn pm(profile: &ModelProfile) -> PerfModel<'_> {
        PerfModel::new(
            profiles::samsung_j6(),
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            profile,
        )
    }

    #[test]
    fn radio_power_matches_paper_constants() {
        let r = RadioPower::PAPER_80211N;
        // P_up at 10 Mbps = 283.17*10 + 132.86 = 2964.56 mW
        assert!((r.upload_power_w(10.0) - 2.96456).abs() < 1e-9);
        assert!((r.download_power_w(10.0) - 1.50296).abs() < 1e-9);
    }

    #[test]
    fn client_power_eq6() {
        let p = model();
        let m = pm(&p);
        // k*C*ν³ = 1.172 * 8 * 1.6³
        let expect = 1.172 * 8.0 * 1.6f64.powi(3);
        assert!((m.client_power_w() - expect).abs() < 1e-12);
    }

    #[test]
    fn upload_latency_is_bits_over_bandwidth() {
        let p = model();
        let m = pm(&p);
        // AlexNet layer 1 output: 64*55*55*4 bytes at 10 Mbps
        let expect = (64.0 * 55.0 * 55.0 * 4.0 * 8.0) / 10e6;
        assert!((m.upload_latency_s(1) - expect).abs() < 1e-12);
    }

    #[test]
    fn client_latency_monotone_in_l1() {
        let p = model();
        let m = pm(&p);
        let mut prev = 0.0;
        for l1 in 1..=21 {
            let t = m.client_latency_s(l1);
            assert!(t >= prev, "client latency must grow with l1");
            prev = t;
        }
    }

    #[test]
    fn server_latency_decreases_in_l1() {
        let p = model();
        let m = pm(&p);
        for l1 in 1..21 {
            assert!(m.server_latency_s(l1) >= m.server_latency_s(l1 + 1));
        }
        assert_eq!(m.server_latency_s(21), 0.0);
    }

    #[test]
    fn cos_has_no_network_terms() {
        let p = model();
        let m = pm(&p);
        let lat = m.latency(21);
        assert_eq!(lat.upload_s, 0.0);
        assert_eq!(lat.download_s, 0.0);
        let e = m.energy(21);
        assert_eq!(e.upload_j, 0.0);
        assert_eq!(e.download_j, 0.0);
    }

    #[test]
    fn coc_uploads_input_image() {
        let p = model();
        let m = pm(&p);
        let lat = m.latency(0);
        assert_eq!(lat.client_s, 0.0);
        let expect = (3.0 * 224.0 * 224.0 * 4.0 * 8.0) / 10e6;
        assert!((lat.upload_s - expect).abs() < 1e-12);
    }

    #[test]
    fn feasibility_bounds() {
        let p = model();
        let m = pm(&p);
        assert!(!m.feasible(0)); // l1 ≥ 1
        assert!(m.feasible(1));
        assert!(m.feasible(20));
        assert!(!m.feasible(21)); // l2 ≥ 1
    }

    #[test]
    fn memory_constraint_enforced() {
        let p = model();
        let mut client = profiles::samsung_j6().clone();
        client.memory_bytes = 1024; // 1 KiB phone
        let m = PerfModel::new(
            &client,
            profiles::cloud_server(),
            RadioPower::PAPER_80211N,
            NetworkEnv::paper_default(),
            &p,
        );
        assert!(!m.feasible(1));
    }

    #[test]
    fn throughput_constraint_enforced() {
        let p = model();
        let net = NetworkEnv { bandwidth_mbps: 10.0, tau_up_mbps: 12.0, tau_down_mbps: 10.0 };
        let m = PerfModel {
            net,
            ..pm(&p)
        };
        assert!(!m.feasible(3));
    }

    #[test]
    fn objectives_consistent_with_breakdowns() {
        let p = model();
        let m = pm(&p);
        for l1 in 1..21 {
            assert_eq!(m.f1(l1), m.latency(l1).total());
            assert_eq!(m.f2(l1), m.energy(l1).total());
            assert_eq!(m.f3(l1), p.client_memory_bytes(l1) as f64);
        }
    }

    #[test]
    fn download_terms_negligible_vs_upload() {
        // The paper drops download latency as negligible; our constants
        // must reproduce that (logits ≪ activations).
        let p = model();
        let m = pm(&p);
        for l1 in 1..21 {
            let lat = m.latency(l1);
            // logits (4 KB) take < 5 ms at 10 Mbps — negligible in absolute
            // terms, and ≪ upload wherever upload carries a conv activation.
            assert!(lat.download_s < 5e-3, "l1={l1} download {}", lat.download_s);
            if l1 <= 12 {
                // conv-trunk activations are ≥ 290 KB: upload dwarfs download
                assert!(lat.download_s < 0.05 * lat.upload_s, "l1={l1}");
            }
        }
    }
}
