//! PJRT runtime: loads the python-AOT per-layer HLO artifacts and executes
//! model segments on the CPU PJRT client (`xla` crate).
//!
//! Design (DESIGN.md §2):
//! * one [`LayerExecutable`] per (layer, batch) — HLO text parsed and
//!   compiled once at load, cached for the process lifetime;
//! * weights are HLO *parameters*: loaded from the manifest's `.bin` files
//!   and **uploaded to device buffers once per model**, then reused by
//!   every request (embedding VGG16's 552 MB as HLO constants would make
//!   multi-GB artifacts and re-upload per compile);
//! * [`ModelRuntime::run_segment`] chains layers `a..=b` entirely in
//!   device buffers (`execute_b`) — activations never round-trip through
//!   host literals between layers. This is what makes the split index a
//!   pure runtime decision (§Perf records literal-path vs buffer-path).

pub mod executor;
pub mod tensor;

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::models::{LayerManifest, Manifest};
pub use tensor::Tensor;

/// One compiled layer (fixed batch size).
pub struct LayerExecutable {
    pub index: usize,
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident weight buffers in manifest order (uploaded at load).
    weights: Vec<xla::PjRtBuffer>,
}

impl LayerExecutable {
    /// Execute on a device-buffer activation, returning a device buffer.
    /// The hot path: no host copies.
    pub fn execute_buf(&self, input: &xla::PjRtBuffer) -> Result<xla::PjRtBuffer> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(input);
        args.extend(self.weights.iter());
        let mut outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("executing layer {}: {e}", self.index))?;
        Ok(outs.remove(0).remove(0))
    }

    /// Host-tensor convenience wrapper (upload → execute → download).
    pub fn execute(&self, client: &xla::PjRtClient, input: &Tensor) -> Result<Tensor> {
        if input.shape != self.in_shape {
            bail!(
                "layer {}: input shape {:?} != expected {:?}",
                self.index, input.shape, self.in_shape
            );
        }
        let buf = input.to_buffer(client)?;
        let out = self.execute_buf(&buf)?;
        Tensor::from_buffer(&out, &self.out_shape)
    }
}

/// All layers of one model at one batch size.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub batch: usize,
    layers: Vec<LayerExecutable>,
    /// Cumulative HLO parse + compile + weight upload time.
    pub load_time: Duration,
    /// Total weight bytes uploaded to the device.
    pub weight_bytes: u64,
}

impl ModelRuntime {
    /// Load and compile layers `[from..=to]` of `model` at `batch`; pass
    /// `1..=num_layers` for the whole model. Loading a sub-range is what a
    /// memory-constrained device does after the split decision.
    pub fn load_range(
        client: &xla::PjRtClient,
        artifacts_dir: &Path,
        model: &str,
        batch: usize,
        from: usize,
        to: usize,
    ) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir, model)?;
        if !manifest.batches.contains(&batch) {
            bail!(
                "model {model} has no batch-{batch} artifacts (available: {:?})",
                manifest.batches
            );
        }
        if from < 1 || to > manifest.num_layers || from > to {
            bail!("bad layer range {from}..={to} for {model} ({} layers)", manifest.num_layers);
        }
        let t0 = Instant::now();
        let mut layers = Vec::with_capacity(to - from + 1);
        let mut weight_bytes = 0u64;
        for lm in &manifest.layers[from - 1..to] {
            let (exe, wb) = Self::load_layer(client, &manifest, lm, batch)?;
            weight_bytes += wb;
            layers.push(exe);
        }
        Ok(ModelRuntime { manifest, batch, layers, load_time: t0.elapsed(), weight_bytes })
    }

    pub fn load(
        client: &xla::PjRtClient,
        artifacts_dir: &Path,
        model: &str,
        batch: usize,
    ) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir, model)?;
        let n = manifest.num_layers;
        Self::load_range(client, artifacts_dir, model, batch, 1, n)
    }

    fn load_layer(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        lm: &LayerManifest,
        batch: usize,
    ) -> Result<(LayerExecutable, u64)> {
        let hlo_path = manifest.hlo_path(lm.index, batch)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", hlo_path.display()))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling layer {} of {}: {e}", lm.index, manifest.model))?;

        let mut weights = Vec::with_capacity(lm.weights.len());
        let mut weight_bytes = 0u64;
        for wm in &lm.weights {
            let t = Tensor::from_bin_file(&manifest.weight_path(wm), &wm.shape)?;
            weight_bytes += t.num_bytes() as u64;
            weights.push(t.to_buffer(client)?);
        }

        // Manifest shapes are batch-1; rescale dim 0.
        let rescale = |s: &[usize]| {
            let mut v = s.to_vec();
            if !v.is_empty() {
                v[0] = batch;
            }
            v
        };
        Ok((
            LayerExecutable {
                index: lm.index,
                kind: lm.kind.clone(),
                in_shape: rescale(&lm.in_shape),
                out_shape: rescale(&lm.out_shape),
                exe,
                weights,
            },
            weight_bytes,
        ))
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// First and last loaded layer indices (1-based, inclusive).
    pub fn loaded_range(&self) -> (usize, usize) {
        (self.layers[0].index, self.layers.last().unwrap().index)
    }

    pub fn layer(&self, index: usize) -> &LayerExecutable {
        let (from, _) = self.loaded_range();
        &self.layers[index - from]
    }

    /// Run layers `from..=to` (1-based, inclusive) on a host tensor; all
    /// intermediate activations stay in device buffers.
    pub fn run_segment(
        &self,
        client: &xla::PjRtClient,
        from: usize,
        to: usize,
        input: &Tensor,
    ) -> Result<Tensor> {
        let (lo, hi) = self.loaded_range();
        if from < lo || to > hi || from > to {
            bail!("bad segment {from}..={to} (loaded {lo}..={hi})");
        }
        let first = self.layer(from);
        if input.shape != first.in_shape {
            bail!(
                "segment {from}..={to}: input {:?} != expected {:?}",
                input.shape, first.in_shape
            );
        }
        let mut buf = input.to_buffer(client)?;
        for i in from..=to {
            buf = self.layer(i).execute_buf(&buf)?;
        }
        Tensor::from_buffer(&buf, &self.layer(to).out_shape)
    }

    /// Full forward pass over the loaded range.
    pub fn run_all(&self, client: &xla::PjRtClient, input: &Tensor) -> Result<Tensor> {
        let (lo, hi) = self.loaded_range();
        self.run_segment(client, lo, hi, input)
    }

    /// Input shape expected by the first loaded layer.
    pub fn input_shape(&self) -> &[usize] {
        &self.layers[0].in_shape
    }

    pub fn output_shape(&self) -> &[usize] {
        &self.layers.last().unwrap().out_shape
    }
}

/// Shared PJRT CPU client + loaded-model cache (keyed by model:batch:range).
pub struct Runtime {
    pub client: xla::PjRtClient,
    models: BTreeMap<String, ModelRuntime>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Runtime { client, models: BTreeMap::new() })
    }

    /// Load (or fetch cached) full model.
    pub fn load_model(
        &mut self,
        artifacts_dir: &Path,
        model: &str,
        batch: usize,
    ) -> Result<&ModelRuntime> {
        let key = format!("{model}:{batch}:all");
        if !self.models.contains_key(&key) {
            let rt = ModelRuntime::load(&self.client, artifacts_dir, model, batch)
                .with_context(|| format!("loading {model} b{batch}"))?;
            log::info!(
                "loaded {model} b{batch}: {} layers, {} weights, {:?}",
                rt.num_layers(),
                crate::util::fmt_bytes(rt.weight_bytes),
                rt.load_time
            );
            self.models.insert(key.clone(), rt);
        }
        Ok(self.models.get(&key).unwrap())
    }

    pub fn get(&self, model: &str, batch: usize) -> Option<&ModelRuntime> {
        self.models.get(&format!("{model}:{batch}:all"))
    }
}
