//! Host-side f32 tensor + conversions to/from PJRT literals and device
//! buffers.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            bail!("tensor shape {shape:?} needs {expect} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn num_elements(&self) -> usize {
        self.data.len()
    }

    pub fn num_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Load a raw little-endian f32 `.bin` weight file (the AOT format).
    pub fn from_bin_file(path: &Path, shape: &[usize]) -> Result<Tensor> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading weight file {}", path.display()))?;
        let expect: usize = shape.iter().product::<usize>() * 4;
        if bytes.len() != expect {
            bail!(
                "weight file {} is {} bytes, shape {shape:?} needs {expect}",
                path.display(),
                bytes.len()
            );
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Convert to a PJRT literal (host).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        // Safety of representation: f32 little-endian byte view.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )
        .map_err(|e| anyhow::anyhow!("literal from tensor: {e}"))
    }

    /// Read back from a PJRT literal; `expect_shape` guards the contract.
    pub fn from_literal(lit: &xla::Literal, expect_shape: &[usize]) -> Result<Tensor> {
        let n: usize = expect_shape.iter().product();
        if lit.element_count() != n {
            bail!(
                "literal has {} elements, expected shape {expect_shape:?} ({n})",
                lit.element_count()
            );
        }
        let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
        Ok(Tensor { shape: expect_shape.to_vec(), data })
    }

    /// Upload to a device buffer (zero extra host copies beyond PJRT's own).
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(&self.data, &self.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading tensor: {e}"))
    }

    /// Download a device buffer.
    pub fn from_buffer(buf: &xla::PjRtBuffer, expect_shape: &[usize]) -> Result<Tensor> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow::anyhow!("buffer sync: {e}"))?;
        Self::from_literal(&lit, expect_shape)
    }

    /// Serialise to little-endian bytes (the wire format of `serve::`).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_le_bytes(shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        let expect: usize = shape.iter().product::<usize>() * 4;
        if bytes.len() != expect {
            bail!("payload is {} bytes, shape {shape:?} needs {expect}", bytes.len());
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { shape, data })
    }

    /// Argmax over the last axis for each row — classification labels.
    pub fn argmax_rows(&self) -> Vec<usize> {
        let cols = *self.shape.last().unwrap_or(&1);
        self.data
            .chunks(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1.5, -2.0, 0.0, 3.25]).unwrap();
        let b = t.to_le_bytes();
        assert_eq!(b.len(), 16);
        let t2 = Tensor::from_le_bytes(vec![2, 2], &b).unwrap();
        assert_eq!(t, t2);
        assert!(Tensor::from_le_bytes(vec![3], &b).is_err());
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 1.0]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn bin_file_roundtrip() {
        let dir = std::env::temp_dir().join("smartsplit_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let t = Tensor::new(vec![3], vec![1.0, 2.5, -7.0]).unwrap();
        std::fs::write(&path, t.to_le_bytes()).unwrap();
        let t2 = Tensor::from_bin_file(&path, &[3]).unwrap();
        assert_eq!(t, t2);
        assert!(Tensor::from_bin_file(&path, &[4]).is_err());
    }
}
