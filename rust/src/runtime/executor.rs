//! Thread-confined PJRT executor.
//!
//! The `xla` crate's handles (`PjRtClient`, `PjRtLoadedExecutable`,
//! `PjRtBuffer`) hold `Rc`s and raw pointers and are neither `Send` nor
//! `Sync`. Rather than `unsafe impl`-ing our way around that, every PJRT
//! object lives on ONE dedicated executor thread; the [`Executor`] handle
//! is a cheap, cloneable `Send` command channel. This also models the
//! paper's testbed faithfully: the phone and the cloud box are each a
//! single compute domain with their own serial inference queue.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::{ModelRuntime, Tensor};

/// Metadata returned by [`Executor::load`].
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub model: String,
    pub batch: usize,
    pub num_layers: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub weight_bytes: u64,
    pub load_time: Duration,
}

enum Cmd {
    Load {
        model: String,
        batch: usize,
        reply: Sender<Result<ModelInfo>>,
    },
    RunSegment {
        model: String,
        batch: usize,
        from: usize,
        to: usize,
        tensor: Tensor,
        reply: Sender<Result<Tensor>>,
    },
    Stop,
}

/// Cloneable, `Send` handle to the PJRT thread.
#[derive(Clone)]
pub struct Executor {
    tx: Sender<Cmd>,
}

impl Executor {
    /// Spawn the executor thread (creates the PJRT CPU client inside it).
    pub fn spawn(artifacts_dir: PathBuf, name: &str) -> Result<Executor> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::Builder::new()
            .name(format!("smartsplit-exec-{name}"))
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("PJRT client: {e}")));
                        return;
                    }
                };
                let mut models: Vec<(String, usize, ModelRuntime)> = Vec::new();
                for cmd in rx {
                    match cmd {
                        Cmd::Load { model, batch, reply } => {
                            let result = if let Some((_, _, rt)) = models
                                .iter()
                                .find(|(m, b, _)| *m == model && *b == batch)
                            {
                                Ok(info_of(&model, batch, rt))
                            } else {
                                match ModelRuntime::load(&client, &artifacts_dir, &model, batch)
                                {
                                    Ok(rt) => {
                                        let info = info_of(&model, batch, &rt);
                                        models.push((model.clone(), batch, rt));
                                        Ok(info)
                                    }
                                    Err(e) => Err(e),
                                }
                            };
                            let _ = reply.send(result);
                        }
                        Cmd::RunSegment { model, batch, from, to, tensor, reply } => {
                            let result = models
                                .iter()
                                .find(|(m, b, _)| *m == model && *b == batch)
                                .ok_or_else(|| anyhow!("{model}:{batch} not loaded"))
                                .and_then(|(_, _, rt)| {
                                    rt.run_segment(&client, from, to, &tensor)
                                });
                            let _ = reply.send(result);
                        }
                        Cmd::Stop => break,
                    }
                }
            })
            .map_err(|e| anyhow!("spawning executor: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(Executor { tx })
    }

    /// Load (idempotently) a model at a batch size.
    pub fn load(&self, model: &str, batch: usize) -> Result<ModelInfo> {
        let (reply, rx) = channel();
        self.tx
            .send(Cmd::Load { model: model.into(), batch, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Run layers `from..=to` of a loaded model.
    pub fn run_segment(
        &self,
        model: &str,
        batch: usize,
        from: usize,
        to: usize,
        tensor: Tensor,
    ) -> Result<Tensor> {
        let (reply, rx) = channel();
        self.tx
            .send(Cmd::RunSegment { model: model.into(), batch, from, to, tensor, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Full forward.
    pub fn run_all(&self, model: &str, batch: usize, tensor: Tensor) -> Result<Tensor> {
        let info = self.load(model, batch)?;
        self.run_segment(model, batch, 1, info.num_layers, tensor)
    }

    /// Stop the executor thread (queued work completes first).
    pub fn stop(&self) {
        let _ = self.tx.send(Cmd::Stop);
    }
}

fn info_of(model: &str, batch: usize, rt: &ModelRuntime) -> ModelInfo {
    ModelInfo {
        model: model.to_string(),
        batch,
        num_layers: rt.num_layers(),
        input_shape: rt.input_shape().to_vec(),
        output_shape: rt.output_shape().to_vec(),
        weight_bytes: rt.weight_bytes,
        load_time: rt.load_time,
    }
}
