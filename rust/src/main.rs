//! SmartSplit CLI — leader entrypoint.
//!
//! Subcommands:
//!   optimize   plan under the analytical model via the planner façade and
//!              print the Pareto set + per-strategy decisions
//!   cloud      run the cloud-side daemon (tail layers)
//!   device     run the device-side client against a cloud daemon
//!   serve      in-process cloud + device + router serving a workload
//!              (alias: demo)
//!   fleet      heterogeneous multi-phone deployment sharing one cloud
//!   simulate   discrete-event fleet simulation (thousands of virtual
//!              devices, diurnal load, churn — no sockets, no wall time)
//!   analyze    trace-plane analytics over simulate's exports: stage
//!              attribution, SLO audit + fault impact, run-vs-run diff
//!   models     list models available in the artifacts directory
//!
//! Every planning subcommand shares the one `--planner <strategy>` flag
//! (declared once, in `util::cli`) and plans exclusively through
//! `planner::Planner`.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use smartsplit::coordinator::{optimize_report, Config, Deployment};
use smartsplit::device::profiles;
use smartsplit::models::Manifest;
use smartsplit::netsim::Link;
use smartsplit::optimizer::Nsga2Params;
use smartsplit::planner::Strategy;
use smartsplit::serve::{CloudServer, DeviceClient, RouterConfig};
use smartsplit::util::cli::Cli;
use smartsplit::workload::{generate, Arrival};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cli() -> Cli {
    Cli::new(
        "smartsplit — CNN split serving between a smartphone and a cloud server\n\
         usage: smartsplit <optimize|cloud|device|serve|fleet|simulate|analyze|models> [flags]",
    )
    .opt("model", "alexnet", "CNN model (alexnet|vgg11|vgg13|vgg16|mobilenet_v2)")
    .opt("batch", "1", "hardware batch size of the loaded artifacts")
    .opt("device-profile", "samsung_j6", "samsung_j6 | redmi_note8")
    .opt("bandwidth-mbps", "10", "link bandwidth B in Mbps")
    .planner_opt()
    .opt("artifacts", "artifacts", "AOT artifacts directory")
    .opt("requests", "16", "number of requests to serve (demo/device)")
    .opt("rps", "0", "open-loop arrival rate; 0 = closed loop")
    .opt("max-batch", "1", "router batching degree (requires matching artifacts)")
    .opt("listen", "127.0.0.1:7700", "cloud listen address")
    .opt("connect", "127.0.0.1:7700", "cloud address to connect to (device)")
    .opt("split", "auto", "split index l1, or 'auto' to run the optimiser")
    .opt("pop", "100", "NSGA-II population size")
    .opt("gens", "250", "NSGA-II generations")
    .opt("seed", "7", "PRNG seed")
    .opt("scenario", "city", "simulate: city | city-tiered | city-mobile | city-faulty | two-phone")
    .opt("devices", "10000", "simulate: fleet size (city scenario)")
    .opt("sim-duration", "10m", "simulate: virtual horizon (90, 90s, 10m, 2h)")
    .opt("clouds", "0", "simulate: cloud count override (0 = scenario default)")
    .opt("cloud-servers", "0", "simulate: servers per cloud override (0 = scenario default)")
    .opt("edge-sites", "0", "simulate: metro edge sites (0 = scenario default: none, or 3 for city-tiered)")
    .opt("edge-servers", "4", "simulate: torso servers per edge site")
    .opt("backhaul", "1000", "simulate: edge→cloud backhaul bandwidth in Mbps")
    .opt("mobility", "scenario", "simulate: device mobility: static | waypoint (scenario = the preset's choice; city-mobile walks by default)")
    .opt("handover-cost", "0.05", "simulate: fixed control-plane cost per edge handover in seconds (torso-state relay over the old backhaul is charged on top)")
    .opt("shards", "1", "simulate: event-engine shards over the edge sites (conservative-lookahead windows; any count replays --shards 1 byte-for-byte)")
    .opt("fault-plan", "", "simulate: fault-injection schedule file (one `<at_s> <kind> <site> [args]` per line; kinds: site-down, site-up, backhaul-degrade, backhaul-restore, flash-crowd); overrides the scenario's plan")
    .opt("trace-out", "", "simulate: enable per-request tracing and write the timeline here (.jsonl = JSON Lines, otherwise Chrome trace_event JSON for chrome://tracing / Perfetto)")
    .opt("trace-sample", "1", "simulate: record every Nth request in the trace (N >= 1; 1 = all; causal annotations are always recorded)")
    .opt("metrics-out", "", "simulate: enable the windowed time-series collector and write its JSON here")
    .opt("metrics-window", "auto", "simulate: time-series window length in virtual seconds (> 0, or 'auto' = horizon / 60)")
    .multi("slo", "SLO clause, repeatable: <p50|p95|p99|mean|max><op><seconds>[s|ms] or drop<op><rate>[%], e.g. --slo 'p99<2.5s' --slo 'drop<0.1%' (simulate/analyze)")
    .opt("report-out", "", "write the versioned analyze report JSON here (simulate/analyze)")
    .opt("trace", "", "analyze: trace JSONL input (written by simulate --trace-out)")
    .opt("metrics", "", "analyze: windowed-metrics JSON input (written by simulate --metrics-out)")
    .opt("baseline", "", "analyze: baseline analyze-report JSON to diff this run against")
    .opt("diff-out", "", "analyze: write the run-vs-run diff JSON here")
    .flag("fail-on-regression", "analyze: exit non-zero when the diff against --baseline contains regressions")
    .flag("no-churn", "simulate: disable device churn")
    .flag("no-slowdown", "disable phone-speed emulation")
    .flag("verbose", "log at info level")
}

fn run(args: &[String]) -> Result<()> {
    let parsed = match cli().parse(args) {
        Ok(p) => p,
        Err(usage) => {
            println!("{usage}");
            return Ok(());
        }
    };
    let cmd = parsed
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("optimize");

    let device_profile = profiles::by_name(parsed.get("device-profile"))
        .context("unknown --device-profile")?;
    // The one strategy parse every subcommand shares (util::cli).
    let strategy = parsed.planner().map_err(|e| anyhow::anyhow!("{e}"))?;
    let cfg = Config {
        artifacts_dir: PathBuf::from(parsed.get("artifacts")),
        model: parsed.get("model").to_string(),
        batch: parsed.get_usize("batch"),
        device_profile,
        bandwidth_mbps: parsed.get_f64("bandwidth-mbps"),
        strategy,
        nsga2: Nsga2Params {
            pop_size: parsed.get_usize("pop"),
            generations: parsed.get_usize("gens"),
            seed: parsed.get_u64("seed"),
            ..Nsga2Params::default()
        },
        router: RouterConfig {
            max_batch: parsed.get_usize("max-batch"),
            ..RouterConfig::default()
        },
        emulate_slowdown: !parsed.get_bool("no-slowdown"),
        seed: parsed.get_u64("seed"),
    };

    match cmd {
        "optimize" => {
            print!("{}", optimize_report(&cfg)?);
        }
        "models" => {
            for m in Manifest::available_models(&cfg.artifacts_dir) {
                let man = Manifest::load(&cfg.artifacts_dir, &m)?;
                println!(
                    "{:<14} {} layers, {} params, batches {:?}, top-1 {:.2}%",
                    m, man.num_layers, man.total_params, man.batches,
                    man.top1_accuracy * 100.0
                );
            }
        }
        "cloud" => {
            let server = CloudServer::bind(parsed.get("listen"), cfg.artifacts_dir.clone())?;
            println!("cloud daemon listening on {}", server.addr);
            let h = server.spawn()?;
            h.join().ok();
        }
        "device" => {
            let split = resolve_split(&cfg, parsed.get("split"))?;
            let link = Arc::new(Link::new(cfg.bandwidth_mbps));
            let mut device = DeviceClient::connect(
                parsed.get("connect"),
                &cfg.artifacts_dir,
                &cfg.model,
                cfg.batch,
                split,
                cfg.device_profile,
                link,
            )?;
            device.emulate_slowdown = cfg.emulate_slowdown;
            serve_on_device(&cfg, Arc::new(device), parsed.get_usize("requests"),
                            parsed.get_f64("rps"))?;
        }
        "fleet" => {
            use smartsplit::coordinator::fleet::{Fleet, FleetConfig, FleetMember};
            let cfg2 = FleetConfig {
                artifacts_dir: cfg.artifacts_dir.clone(),
                model: cfg.model.clone(),
                batch: cfg.batch,
                members: vec![
                    FleetMember { profile: profiles::samsung_j6(), bandwidth_mbps: cfg.bandwidth_mbps },
                    FleetMember { profile: profiles::redmi_note8(), bandwidth_mbps: cfg.bandwidth_mbps * 3.0 },
                ],
                strategy: cfg.strategy,
                nsga2: cfg.nsga2.clone(),
                emulate_slowdown: cfg.emulate_slowdown,
            };
            let fleet = Fleet::start(cfg2)?;
            println!("fleet splits: {:?}", fleet.splits());
            let reqs = generate(parsed.get_usize("requests"),
                                arrival_of(parsed.get_f64("rps")), cfg.seed);
            let report = fleet.serve(&reqs)?;
            report.print();
            fleet.shutdown();
        }
        "serve" | "demo" => {
            let n = parsed.get_usize("requests");
            let arrival = arrival_of(parsed.get_f64("rps"));
            println!("planning split for {} on {} @ {} Mbps using {}...",
                     cfg.model, cfg.device_profile.name, cfg.bandwidth_mbps,
                     cfg.strategy.name());
            let dep = match parsed.get("split") {
                "auto" => Deployment::start(cfg.clone())?,
                s => Deployment::start_with_split(
                    cfg.clone(),
                    smartsplit::optimizer::SplitDecision { l1: s.parse()? },
                )?,
            };
            println!("split: l1={} (device) / l2={} (cloud)", dep.split.l1,
                     dep.device.num_layers() - dep.split.l1);
            let reqs = generate(n, arrival, cfg.seed);
            let report = dep.serve(&reqs)?;
            report.print();
            dep.shutdown();
        }
        "simulate" => {
            use smartsplit::sim;
            let duration = parsed.get_duration_s("sim-duration");
            let edge_sites = parsed.get_usize("edge-sites");
            let mut sim_cfg = match parsed.get("scenario") {
                "city" => sim::city_scale(
                    &cfg.model,
                    parsed.get_usize("devices"),
                    duration,
                    cfg.seed,
                ),
                "city-tiered" => sim::city_scale_tiered(
                    &cfg.model,
                    parsed.get_usize("devices"),
                    if edge_sites > 0 { edge_sites } else { 3 },
                    duration,
                    cfg.seed,
                ),
                "city-mobile" => sim::city_mobile(
                    &cfg.model,
                    parsed.get_usize("devices"),
                    if edge_sites > 0 { edge_sites } else { 3 },
                    duration,
                    cfg.seed,
                ),
                "city-faulty" => sim::city_faulty(
                    &cfg.model,
                    parsed.get_usize("devices"),
                    if edge_sites > 0 { edge_sites } else { 3 },
                    duration,
                    cfg.seed,
                ),
                "two-phone" => {
                    // Fleet-simulation default: the small split genome
                    // needs nowhere near the canonical 100×250 budget, so
                    // unless the user explicitly passed --pop/--gens (even
                    // at the canonical values), plan with the small-genome
                    // preset sized for the genome the run actually solves.
                    let nsga2 = if parsed.provided("pop") || parsed.provided("gens") {
                        cfg.nsga2.clone()
                    } else {
                        let dim = if edge_sites > 0 { 2 } else { 1 };
                        Nsga2Params { seed: cfg.seed, ..Nsga2Params::for_small_genome(dim) }
                    };
                    let mut c = sim::two_phone_fleet(
                        &cfg.model,
                        cfg.bandwidth_mbps,
                        nsga2,
                        cfg.seed,
                    );
                    c.duration_s = duration;
                    c
                }
                other => bail!(
                    "unknown --scenario {other:?} (city | city-tiered | city-mobile | city-faulty | two-phone)"
                ),
            };
            if parsed.get_usize("clouds") > 0 {
                sim_cfg.clouds = parsed.get_usize("clouds");
            }
            if parsed.get_usize("cloud-servers") > 0 {
                sim_cfg.cloud_servers = parsed.get_usize("cloud-servers");
            }
            // --edge-sites attaches the metro edge tier on any scenario
            // without one (city-tiered already resolved its site count
            // above); --edge-servers / --backhaul override the matching
            // field of a preset-attached tier without discarding the
            // preset's other choices.
            if let Some(spec) = sim_cfg.edge.as_mut() {
                if parsed.provided("edge-servers") {
                    spec.servers_per_site = parsed.get_usize("edge-servers");
                }
                if parsed.provided("backhaul") {
                    spec.backhaul.bandwidth_mbps = parsed.get_f64("backhaul");
                }
            } else if edge_sites > 0 {
                sim_cfg.edge = Some(sim::EdgeSpec::uniform(
                    edge_sites,
                    parsed.get_usize("edge-servers"),
                    parsed.get_f64("backhaul"),
                ));
            }
            // --mobility overrides the preset's mobility model on any
            // scenario with an edge tier (city-mobile walks by default;
            // `--mobility static` freezes it back into the byte-exact
            // immobile replay). --handover-cost tunes the fixed
            // control-plane part of each handover.
            if parsed.provided("mobility") {
                sim_cfg.mobility = match parsed.get("mobility").to_ascii_lowercase().as_str() {
                    "static" => sim::Mobility::Static,
                    "waypoint" => {
                        sim::Mobility::Waypoint(sim::WaypointWalk::city_default(duration))
                    }
                    "scenario" => sim_cfg.mobility,
                    other => bail!("unknown --mobility {other:?} (static | waypoint)"),
                };
            }
            if parsed.provided("handover-cost") {
                sim_cfg.handover_cost_s = parsed.get_f64("handover-cost");
            }
            // --shards partitions the event engine over the edge sites
            // (DESIGN.md §16). Pure wall-clock knob: every count must
            // replay --shards 1 byte-for-byte, so no scenario guard is
            // needed beyond the engine's own shards >= 1 validation.
            if parsed.provided("shards") {
                sim_cfg.shards = parsed.get_usize("shards");
            }
            // --fault-plan replaces the scenario's fault schedule with a
            // file-scripted one (city-faulty ships a built-in schedule;
            // every other preset defaults to none). Parse errors carry
            // the offending line and, for unknown kinds, the valid-name
            // list — the run never starts on a bad plan.
            let fault_plan_path = parsed.get("fault-plan");
            if !fault_plan_path.is_empty() {
                let text = std::fs::read_to_string(fault_plan_path)
                    .with_context(|| format!("reading --fault-plan {fault_plan_path}"))?;
                sim_cfg.faults = sim::FaultPlan::parse(&text)
                    .map_err(|e| anyhow::anyhow!("--fault-plan {fault_plan_path}: {e}"))?;
            }
            // --planner overrides the scenario's default strategy
            // (city presets default to Topsis, two-phone to SmartSplit);
            // the sim maps it onto its planner with a genome-sized
            // NSGA-II budget when Algorithm 1 is asked for.
            if parsed.provided("planner") {
                sim_cfg.planner = match strategy {
                    Strategy::SmartSplit => {
                        let dim = if sim_cfg.edge.is_some() { 2 } else { 1 };
                        sim::Planner::SmartSplit(Nsga2Params {
                            seed: cfg.seed,
                            ..Nsga2Params::for_small_genome(dim)
                        })
                    }
                    Strategy::Topsis => sim::Planner::Topsis,
                    s => {
                        // Simulated devices must always get a plan;
                        // the ε box can legitimately be infeasible and
                        // would abort the run mid-flight.
                        anyhow::ensure!(
                            s != Strategy::EpsilonConstrained,
                            "--planner EpsilonConstrained can find no feasible split under its \
                             fixed ε ceilings and would abort the simulation; use a total \
                             strategy here (see `optimize --planner epsilonconstrained` for the \
                             analytical view)"
                        );
                        sim::Planner::Custom(s)
                    }
                };
            }
            if parsed.get_bool("no-churn") {
                sim_cfg.churn = None;
            }
            // Observability is opt-in per sink: --trace-out turns the
            // span recorder on, --metrics-out the windowed collector.
            // Neither perturbs decisions or event order (DESIGN.md §12).
            // Asking for analysis (--slo / --report-out) implies both
            // sinks: attribution needs spans, SLO windows need the
            // series (DESIGN.md §14).
            let trace_out = parsed.get("trace-out").to_string();
            let metrics_out = parsed.get("metrics-out").to_string();
            let report_out = parsed.get("report-out").to_string();
            let slos = parse_slos(parsed.get_multi("slo"))?;
            let analysis_requested = !report_out.is_empty() || !slos.is_empty();
            if !trace_out.is_empty() || analysis_requested {
                let every = parsed.get_u64("trace-sample");
                if every == 0 {
                    bail!(
                        "--trace-sample 0 is out of range: the recorder keeps every Nth \
                         request, so N must be >= 1 (1 = every request)"
                    );
                }
                sim_cfg.observability.trace_sample_every = every;
            }
            if !metrics_out.is_empty() || analysis_requested {
                sim_cfg.observability.window_s = match parsed.get("metrics-window") {
                    "auto" => sim_cfg.duration_s / 60.0,
                    raw => {
                        let w: f64 = raw
                            .parse()
                            .map_err(|_| anyhow::anyhow!("--metrics-window {raw:?} is not a number"))?;
                        if !w.is_finite() || w <= 0.0 {
                            bail!(
                                "--metrics-window {raw} is out of range: the window length \
                                 must be a finite number of virtual seconds > 0 (or 'auto' \
                                 = horizon / 60)"
                            );
                        }
                        w
                    }
                };
            }
            println!(
                "simulating {} device(s) of {} for {:.0}s virtual (seed {}{}{})...",
                sim_cfg.fleet.initial_count(),
                sim_cfg.model,
                sim_cfg.duration_s,
                sim_cfg.seed,
                match &sim_cfg.edge {
                    Some(e) => format!(
                        ", {} edge sites × {} servers @ {} Mbps backhaul",
                        e.sites, e.servers_per_site, e.backhaul.bandwidth_mbps
                    ),
                    None => String::new(),
                },
                if sim_cfg.mobility.is_mobile() {
                    format!(", waypoint mobility @ {:.0} ms handover", sim_cfg.handover_cost_s * 1e3)
                } else {
                    String::new()
                },
            );
            if !sim_cfg.faults.is_empty() {
                println!("  injecting {} scheduled fault(s)", sim_cfg.faults.events.len());
            }
            if sim_cfg.shards > 1 {
                println!("  event engine sharded {}-way (replays --shards 1 byte-for-byte)", sim_cfg.shards);
            }
            let report = sim::run(&sim_cfg)?;
            report.print();
            if !metrics_out.is_empty() {
                let doc = report
                    .metrics_json()
                    .expect("--metrics-out enabled the collector");
                std::fs::write(&metrics_out, doc.to_string_pretty())
                    .with_context(|| format!("writing --metrics-out {metrics_out}"))?;
                let n = report.series.as_ref().map_or(0, |ts| ts.windows.len());
                println!("wrote windowed metrics ({n} windows) to {metrics_out}");
            }
            if !trace_out.is_empty() {
                let tr = report.trace.as_ref().expect("--trace-out enabled tracing");
                tr.export(std::path::Path::new(&trace_out))
                    .with_context(|| format!("writing --trace-out {trace_out}"))?;
                println!(
                    "wrote {} request timelines + {} causal events to {trace_out}",
                    tr.requests.len(),
                    tr.events.len()
                );
            }
            if analysis_requested {
                use smartsplit::analyze::{AnalyzeReport, RunData};
                let data = RunData::from_report(&report)?;
                let analysis = AnalyzeReport::build(&data, &slos);
                println!();
                analysis.print();
                if !report_out.is_empty() {
                    std::fs::write(&report_out, analysis.to_json().to_string_pretty())
                        .with_context(|| format!("writing --report-out {report_out}"))?;
                    println!("wrote analyze report to {report_out}");
                }
            }
        }
        "analyze" => {
            use smartsplit::analyze::{diff_reports, AnalyzeReport, RunData};
            let trace_path = parsed.get("trace");
            let metrics_path = parsed.get("metrics");
            if trace_path.is_empty() && metrics_path.is_empty() {
                bail!(
                    "analyze needs at least one input: --trace <file.jsonl> (from simulate \
                     --trace-out) and/or --metrics <file.json> (from simulate --metrics-out)"
                );
            }
            let slos = parse_slos(parsed.get_multi("slo"))?;
            let data = RunData::from_export_files(
                (!trace_path.is_empty()).then(|| std::path::Path::new(trace_path)),
                (!metrics_path.is_empty()).then(|| std::path::Path::new(metrics_path)),
            )?;
            let analysis = AnalyzeReport::build(&data, &slos);
            analysis.print();
            let doc = analysis.to_json();
            let report_out = parsed.get("report-out");
            if !report_out.is_empty() {
                std::fs::write(report_out, doc.to_string_pretty())
                    .with_context(|| format!("writing --report-out {report_out}"))?;
                println!("wrote analyze report to {report_out}");
            }
            let baseline = parsed.get("baseline");
            if !baseline.is_empty() {
                let text = std::fs::read_to_string(baseline)
                    .with_context(|| format!("reading --baseline {baseline}"))?;
                let base = smartsplit::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing --baseline {baseline}"))?;
                let d = diff_reports(&base, &doc);
                println!();
                d.print();
                let diff_out = parsed.get("diff-out");
                if !diff_out.is_empty() {
                    std::fs::write(diff_out, d.to_json().to_string_pretty())
                        .with_context(|| format!("writing --diff-out {diff_out}"))?;
                    println!("wrote diff report to {diff_out}");
                }
                if parsed.get_bool("fail-on-regression") && d.regressions > 0 {
                    bail!(
                        "{} regression(s) against --baseline {baseline}",
                        d.regressions
                    );
                }
            }
        }
        other => bail!("unknown command {other:?} (try --help)"),
    }
    Ok(())
}

/// Parse every repeated `--slo` clause, attaching the offending clause to
/// the grammar error so the message teaches the fix.
fn parse_slos(raws: &[String]) -> Result<Vec<smartsplit::analyze::Slo>> {
    raws.iter()
        .map(|r| {
            smartsplit::analyze::Slo::parse(r).map_err(|e| anyhow::anyhow!("--slo {r:?}: {e}"))
        })
        .collect()
}

fn arrival_of(rps: f64) -> Arrival {
    if rps > 0.0 {
        Arrival::Poisson { rps }
    } else {
        Arrival::ClosedLoop
    }
}

fn resolve_split(cfg: &Config, s: &str) -> Result<usize> {
    if s == "auto" {
        Ok(smartsplit::coordinator::plan_split(cfg)?.l1)
    } else {
        Ok(s.parse()?)
    }
}

fn serve_on_device(
    cfg: &Config,
    device: Arc<DeviceClient>,
    n: usize,
    rps: f64,
) -> Result<()> {
    use smartsplit::metrics::Histogram;
    use smartsplit::runtime::Tensor;
    use smartsplit::serve::Router;
    use smartsplit::workload::synth_images;

    let router = Router::start(Arc::clone(&device), cfg.router.clone())?;
    let latency = Histogram::new();
    let reqs = generate(n, arrival_of(rps), cfg.seed);
    let shape = device.input_shape().to_vec();
    // detlint:allow(D1): live serving CLI pacing against real sockets
    let start = std::time::Instant::now();
    for req in &reqs {
        let now = start.elapsed();
        if req.arrival > now {
            std::thread::sleep(req.arrival - now);
        }
        let img = Tensor::new(
            vec![1, shape[1], shape[2], shape[3]],
            synth_images(1, shape[1], shape[2], req.image_seed),
        )?;
        let c = router.infer_blocking(req.id, img)?;
        latency.record_secs(c.timing.total_s);
        println!("request {} → label {} in {:.3}s (batch {})",
                 c.id, c.label, c.timing.total_s, c.batch_size);
    }
    router.stop();
    println!("latency: {}", latency.summary());
    println!(
        "energy: client {:.2} J, upload {:.2} J, download {:.2} J",
        device.energy.client_j(), device.energy.upload_j(), device.energy.download_j()
    );
    device.shutdown()?;
    device.stop();
    Ok(())
}
