//! Windowed time-series telemetry: fixed virtual-time windows over the
//! simulated request path (DESIGN.md §12).
//!
//! The whole-run aggregates in [`super`] answer *what happened*; this
//! collector answers *when*: per-window latency quantiles per tier,
//! per-site queue depth and utilisation, planner cache hit rate, and
//! handover/migration rates. Windows are `[k·w, (k+1)·w)` on the virtual
//! clock — an event stamped exactly on a boundary opens the next window
//! — so the series is a pure function of the event stream and therefore
//! byte-identical across thread configs and repeat runs.
//!
//! Memory discipline: only the *current* window holds live histograms
//! (four log-bucketed [`Histogram`]s); every closed window is flattened
//! to a [`WindowSummary`] of plain numbers, so a long run with small
//! windows stays cheap.

use super::{Histogram, PlannerStats};
use crate::util::json::Json;

/// Schema version of the `--metrics-out` document
/// ([`crate::sim::SimReport::metrics_json`] stamps it;
/// `.github/check_observability.py` and [`crate::analyze`] validate it).
pub const METRICS_SCHEMA_VERSION: u64 = 1;

/// Boundary snapshot of one M/G/c pool (edge site or cloud), taken by
/// the caller when a window closes. `busy_time_s` is the pool's
/// cumulative committed service time — the collector differences
/// consecutive snapshots to get per-window utilisation.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauge {
    pub queue_len: usize,
    pub busy_time_s: f64,
    pub servers: usize,
}

/// One tier's latency distribution inside one window, flattened.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TierWindow {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl TierWindow {
    fn from_hist(h: &Histogram) -> TierWindow {
        TierWindow {
            count: h.count(),
            mean_s: h.mean_s(),
            p50_s: h.p50(),
            p95_s: h.p95(),
            p99_s: h.p99(),
            max_s: h.max_s(),
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("p99_s", Json::Num(self.p99_s)),
            ("max_s", Json::Num(self.max_s)),
        ])
    }
}

/// One pool's state over one window: queue depth at the closing
/// boundary, utilisation over the window (committed service time /
/// server-seconds — unclamped, like `utilization()` on the pools, so a
/// backlog burning down can legitimately exceed 1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolWindow {
    pub queue_depth: usize,
    pub utilization: f64,
}

impl PoolWindow {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("utilization", Json::Num(self.utilization)),
        ])
    }
}

/// A closed window, flattened to plain numbers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowSummary {
    /// Window ordinal: this window covers `[index·w, end_s)`.
    pub index: u64,
    pub start_s: f64,
    /// End boundary — `(index+1)·w` for full windows, the horizon for a
    /// partial tail window.
    pub end_s: f64,
    pub generated: u64,
    pub completed: u64,
    pub dropped: u64,
    pub resplits: u64,
    pub handovers: u64,
    pub migration_replans: u64,
    /// Failover actions inside this window: outage-forced reattaches
    /// plus requests rerouted to the cloud off a dead site. Per-window
    /// values partition the run total (`tests/fault_injection.rs`).
    pub failovers: u64,
    /// Number of fault conditions active at the window's close boundary
    /// (a gauge, not a rate: outages + brownouts + flash crowds in
    /// progress).
    pub faults_active: u64,
    /// Planner cache traffic inside this window (façade requests from
    /// any thread land here when the window closes).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// End-to-end latency of requests *completing* in this window.
    pub latency: TierWindow,
    pub device_queue: TierWindow,
    pub edge_queue: TierWindow,
    pub cloud_queue: TierWindow,
    pub edges: Vec<PoolWindow>,
    pub clouds: Vec<PoolWindow>,
}

impl WindowSummary {
    /// Planner cache hit rate inside this window, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / total as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::Num(self.index as f64)),
            ("start_s", Json::Num(self.start_s)),
            ("end_s", Json::Num(self.end_s)),
            ("generated", Json::Num(self.generated as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("resplits", Json::Num(self.resplits as f64)),
            ("handovers", Json::Num(self.handovers as f64)),
            ("migration_replans", Json::Num(self.migration_replans as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("faults_active", Json::Num(self.faults_active as f64)),
            (
                "planner",
                Json::obj(vec![
                    ("cache_hits", Json::Num(self.cache_hits as f64)),
                    ("cache_misses", Json::Num(self.cache_misses as f64)),
                    ("hit_rate", Json::Num(self.hit_rate())),
                ]),
            ),
            ("latency", self.latency.to_json()),
            ("device_queue", self.device_queue.to_json()),
            ("edge_queue", self.edge_queue.to_json()),
            ("cloud_queue", self.cloud_queue.to_json()),
            ("edges", Json::Arr(self.edges.iter().map(|p| p.to_json()).collect())),
            ("clouds", Json::Arr(self.clouds.iter().map(|p| p.to_json()).collect())),
        ])
    }
}

/// The finalized series: every window in order, ready for `SimReport`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TimeSeriesReport {
    pub window_s: f64,
    pub windows: Vec<WindowSummary>,
}

impl TimeSeriesReport {
    /// Deterministic JSON (insertion-ordered objects; the `--metrics-out`
    /// payload embeds this under `"series"`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::Num(self.window_s)),
            ("windows", Json::Arr(self.windows.iter().map(|w| w.to_json()).collect())),
        ])
    }

    /// Per-window planner hit rates, in window order (the
    /// `planner_throughput` bench tracks this curve in
    /// `BENCH_planner.json`).
    pub fn hit_rate_curve(&self) -> Vec<f64> {
        self.windows.iter().map(|w| w.hit_rate()).collect()
    }

    /// Compact per-window console table (one line per window).
    pub fn print_brief(&self) {
        println!(
            "  series     : {} windows of {:.1}s (virtual)",
            self.windows.len(),
            self.window_s
        );
        for w in &self.windows {
            println!(
                "    [{:>3}] {:>7.1}-{:<7.1} gen={:<6} done={:<6} p95={} hit={:>3.0}% ho={} mig={} fo={} faults={}",
                w.index,
                w.start_s,
                w.end_s,
                w.generated,
                w.completed,
                crate::util::fmt_secs(w.latency.p95_s),
                w.hit_rate() * 100.0,
                w.handovers,
                w.migration_replans,
                w.failovers,
                w.faults_active,
            );
        }
    }
}

/// Live accumulator for the current window.
#[derive(Debug, Default)]
struct WindowAcc {
    generated: u64,
    completed: u64,
    dropped: u64,
    resplits: u64,
    handovers: u64,
    migration_replans: u64,
    failovers: u64,
    latency: Histogram,
    device_queue: Histogram,
    edge_queue: Histogram,
    cloud_queue: Histogram,
}

impl WindowAcc {
    /// True when nothing was recorded since the last close — used by
    /// [`TimeSeries::finalize`] to decide whether an exact-boundary
    /// horizon still owes a (zero-width) flush.
    fn is_empty(&self) -> bool {
        self.generated == 0
            && self.completed == 0
            && self.dropped == 0
            && self.resplits == 0
            && self.handovers == 0
            && self.migration_replans == 0
            && self.failovers == 0
            && self.latency.count() == 0
            && self.device_queue.count() == 0
            && self.edge_queue.count() == 0
            && self.cloud_queue.count() == 0
    }
}

/// The collector: record hooks fill the current window; [`TimeSeries::roll`]
/// closes it (possibly several, when the clock jumps over quiet windows)
/// whenever the virtual clock crosses a boundary.
#[derive(Debug)]
pub struct TimeSeries {
    window_s: f64,
    cur_idx: u64,
    cur: WindowAcc,
    /// Planner counters at the last window close — windows report deltas.
    planner_base: PlannerStats,
    /// `busy_time_s` per edge site / cloud at the last window close.
    edge_busy_base: Vec<f64>,
    cloud_busy_base: Vec<f64>,
    /// Live count of in-progress fault conditions, set by the fault
    /// injector; snapshotted into every window it closes over.
    faults_active: u64,
    closed: Vec<WindowSummary>,
}

impl TimeSeries {
    /// `window_s` must be positive; callers gate collection on a
    /// configured window, so a non-positive width is a config bug.
    pub fn new(window_s: f64, n_edges: usize, n_clouds: usize) -> TimeSeries {
        assert!(window_s > 0.0, "time-series window must be positive, got {window_s}");
        TimeSeries {
            window_s,
            cur_idx: 0,
            cur: WindowAcc::default(),
            planner_base: PlannerStats {
                cache_hits: 0,
                cache_misses: 0,
                solves: 0,
                requests_by_reason: [0; super::REPLAN_REASONS],
            },
            edge_busy_base: vec![0.0; n_edges],
            cloud_busy_base: vec![0.0; n_clouds],
            faults_active: 0,
            closed: Vec::new(),
        }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Cheap pre-check: does the clock at `t` sit past the current
    /// window? Callers test this before assembling the (more expensive)
    /// pool gauges that [`TimeSeries::roll`] needs.
    pub fn needs_roll(&self, t: f64) -> bool {
        t >= (self.cur_idx + 1) as f64 * self.window_s
    }

    // ------------------------------------------------------ record hooks

    pub fn on_generated(&mut self) {
        self.cur.generated += 1;
    }

    pub fn on_completed(&mut self, latency_s: f64) {
        self.cur.completed += 1;
        self.cur.latency.record_secs(latency_s);
    }

    pub fn on_dropped(&mut self, n: u64) {
        self.cur.dropped += n;
    }

    pub fn on_resplit(&mut self) {
        self.cur.resplits += 1;
    }

    pub fn on_handover(&mut self) {
        self.cur.handovers += 1;
    }

    pub fn on_migration(&mut self) {
        self.cur.migration_replans += 1;
    }

    /// One failover action: an outage-forced reattach or a request
    /// rerouted to the cloud off a dead site.
    pub fn on_failover(&mut self) {
        self.cur.failovers += 1;
    }

    /// Update the active-fault gauge; the value at a window's close
    /// boundary is what the window reports.
    pub fn set_faults_active(&mut self, n: u64) {
        self.faults_active = n;
    }

    pub fn on_device_wait(&mut self, s: f64) {
        self.cur.device_queue.record_secs(s);
    }

    pub fn on_edge_wait(&mut self, s: f64) {
        self.cur.edge_queue.record_secs(s);
    }

    pub fn on_cloud_wait(&mut self, s: f64) {
        self.cur.cloud_queue.record_secs(s);
    }

    // ------------------------------------------------------------- close

    /// Close every window whose end boundary is `<= t` (quiet windows in
    /// between close empty — the series stays contiguous). `planner` is
    /// the *cumulative* stats snapshot and the gauges the *cumulative*
    /// pool states; the collector differences them against the previous
    /// boundary.
    pub fn roll(&mut self, t: f64, planner: PlannerStats, edges: &[PoolGauge], clouds: &[PoolGauge]) {
        while self.needs_roll(t) {
            let end = (self.cur_idx + 1) as f64 * self.window_s;
            self.close_current(end, planner, edges, clouds);
        }
    }

    fn close_current(
        &mut self,
        end_s: f64,
        planner: PlannerStats,
        edges: &[PoolGauge],
        clouds: &[PoolGauge],
    ) {
        let start_s = self.cur_idx as f64 * self.window_s;
        let dur = (end_s - start_s).max(0.0);
        let acc = std::mem::take(&mut self.cur);
        let pool_windows = |gauges: &[PoolGauge], base: &mut Vec<f64>| -> Vec<PoolWindow> {
            gauges
                .iter()
                .enumerate()
                .map(|(i, g)| {
                    let prev = base.get(i).copied().unwrap_or(0.0);
                    if base.len() <= i {
                        base.resize(i + 1, 0.0);
                    }
                    base[i] = g.busy_time_s;
                    let utilization = if g.servers == 0 || dur <= 0.0 {
                        0.0
                    } else {
                        (g.busy_time_s - prev) / (g.servers as f64 * dur)
                    };
                    PoolWindow { queue_depth: g.queue_len, utilization }
                })
                .collect()
        };
        let edge_windows = pool_windows(edges, &mut self.edge_busy_base);
        let cloud_windows = pool_windows(clouds, &mut self.cloud_busy_base);
        self.closed.push(WindowSummary {
            index: self.cur_idx,
            start_s,
            end_s,
            generated: acc.generated,
            completed: acc.completed,
            dropped: acc.dropped,
            resplits: acc.resplits,
            handovers: acc.handovers,
            migration_replans: acc.migration_replans,
            failovers: acc.failovers,
            faults_active: self.faults_active,
            cache_hits: planner.cache_hits - self.planner_base.cache_hits,
            cache_misses: planner.cache_misses - self.planner_base.cache_misses,
            latency: TierWindow::from_hist(&acc.latency),
            device_queue: TierWindow::from_hist(&acc.device_queue),
            edge_queue: TierWindow::from_hist(&acc.edge_queue),
            cloud_queue: TierWindow::from_hist(&acc.cloud_queue),
            edges: edge_windows,
            clouds: cloud_windows,
        });
        self.planner_base = planner;
        self.cur_idx += 1;
    }

    /// Close out the run at `end_s`: full windows first, then a partial
    /// tail window iff the horizon lands strictly inside one — or, when
    /// the horizon sits exactly on a boundary but events were recorded
    /// *at* that boundary after the last roll (roll-before-dispatch puts
    /// a boundary-stamped event into the next window), a zero-width
    /// flush window, so per-window counters always partition the run
    /// totals exactly (`tests/observability.rs` pins the property).
    pub fn finalize(
        mut self,
        end_s: f64,
        planner: PlannerStats,
        edges: &[PoolGauge],
        clouds: &[PoolGauge],
    ) -> TimeSeriesReport {
        self.roll(end_s, planner, edges, clouds);
        let tail_start = self.cur_idx as f64 * self.window_s;
        let planner_delta_pending = planner.cache_hits > self.planner_base.cache_hits
            || planner.cache_misses > self.planner_base.cache_misses;
        if end_s > tail_start || !self.cur.is_empty() || planner_delta_pending {
            self.close_current(end_s.max(tail_start), planner, edges, clouds);
        }
        TimeSeriesReport { window_s: self.window_s, windows: self.closed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(hits: u64, misses: u64) -> PlannerStats {
        PlannerStats {
            cache_hits: hits,
            cache_misses: misses,
            solves: misses,
            requests_by_reason: [0; crate::metrics::REPLAN_REASONS],
        }
    }

    #[test]
    fn windows_are_contiguous_even_across_quiet_gaps() {
        let mut ts = TimeSeries::new(10.0, 0, 1);
        ts.on_generated();
        ts.on_completed(0.5);
        // The clock jumps straight to 35s: windows 0, 1, 2 must all
        // close (1 and 2 empty), and the tail [30, 35) is partial.
        let gauges = [PoolGauge { queue_len: 0, busy_time_s: 5.0, servers: 2 }];
        ts.roll(35.0, stats(3, 1), &[], &gauges);
        ts.on_completed(1.0);
        let report = ts.finalize(35.0, stats(4, 1), &[], &gauges);
        assert_eq!(report.windows.len(), 4);
        for (i, w) in report.windows.iter().enumerate() {
            assert_eq!(w.index, i as u64);
            assert_eq!(w.start_s, i as f64 * 10.0);
        }
        for pair in report.windows.windows(2) {
            assert_eq!(pair[0].end_s, pair[1].start_s, "gap in the series");
        }
        assert_eq!(report.windows[0].completed, 1);
        assert_eq!(report.windows[1].completed, 0);
        assert_eq!(report.windows[3].end_s, 35.0);
        assert_eq!(report.windows[3].completed, 1);
        // Totals are conserved across windows.
        let total: u64 = report.windows.iter().map(|w| w.completed).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn planner_deltas_and_hit_rate_per_window() {
        let mut ts = TimeSeries::new(1.0, 0, 0);
        ts.roll(1.0, stats(2, 2), &[], &[]);
        ts.roll(2.0, stats(8, 2), &[], &[]);
        let report = ts.finalize(2.0, stats(8, 2), &[], &[]);
        assert_eq!(report.windows.len(), 2);
        assert_eq!((report.windows[0].cache_hits, report.windows[0].cache_misses), (2, 2));
        assert_eq!((report.windows[1].cache_hits, report.windows[1].cache_misses), (6, 0));
        assert!((report.windows[0].hit_rate() - 0.5).abs() < 1e-12);
        assert!((report.windows[1].hit_rate() - 1.0).abs() < 1e-12);
        assert_eq!(report.hit_rate_curve(), vec![0.5, 1.0]);
    }

    #[test]
    fn pool_utilization_differences_busy_time() {
        let mut ts = TimeSeries::new(10.0, 1, 0);
        // 4s of committed service on a 2-server site over a 10s window.
        ts.roll(10.0, stats(0, 0), &[PoolGauge { queue_len: 3, busy_time_s: 4.0, servers: 2 }], &[]);
        // 4 more seconds over the next window.
        let report = ts.finalize(
            20.0,
            stats(0, 0),
            &[PoolGauge { queue_len: 0, busy_time_s: 8.0, servers: 2 }],
            &[],
        );
        assert_eq!(report.windows.len(), 2);
        assert_eq!(report.windows[0].edges[0].queue_depth, 3);
        assert!((report.windows[0].edges[0].utilization - 0.2).abs() < 1e-12);
        assert!((report.windows[1].edges[0].utilization - 0.2).abs() < 1e-12);
        assert_eq!(report.windows[1].edges[0].queue_depth, 0);
    }

    #[test]
    fn relay_only_pool_reports_zero_utilization() {
        let ts = TimeSeries::new(5.0, 1, 0);
        let gauge = [PoolGauge { queue_len: 0, busy_time_s: 0.0, servers: 0 }];
        let report = ts.finalize(5.0, stats(0, 0), &gauge, &[]);
        assert_eq!(report.windows[0].edges[0].utilization, 0.0);
    }

    #[test]
    fn exact_horizon_boundary_emits_no_empty_tail() {
        let mut ts = TimeSeries::new(10.0, 0, 0);
        ts.on_completed(0.1);
        let report = ts.finalize(20.0, stats(0, 0), &[], &[]);
        assert_eq!(report.windows.len(), 2, "horizon on a boundary must not add a tail");
        assert_eq!(report.windows[1].end_s, 20.0);
    }

    #[test]
    fn boundary_stamped_events_flush_in_a_zero_width_tail() {
        // Roll-before-dispatch: an event at exactly t=10 rolls window 0
        // closed, then records into window 1. If the run then drains at
        // exactly t=10, those events must still be reported — as a
        // zero-width tail window — or the per-window counters would no
        // longer partition the run totals.
        let mut ts = TimeSeries::new(10.0, 0, 0);
        ts.on_completed(0.5);
        ts.roll(10.0, stats(0, 0), &[], &[]);
        ts.on_generated();
        ts.on_completed(1.0);
        ts.on_failover();
        let report = ts.finalize(10.0, stats(2, 1), &[], &[]);
        assert_eq!(report.windows.len(), 2);
        let tail = &report.windows[1];
        assert_eq!((tail.start_s, tail.end_s), (10.0, 10.0));
        assert_eq!((tail.generated, tail.completed, tail.failovers), (1, 1, 1));
        assert_eq!((tail.cache_hits, tail.cache_misses), (2, 1));
        let completed: u64 = report.windows.iter().map(|w| w.completed).sum();
        assert_eq!(completed, 2, "flush lost completions");
        // A pure planner delta (no accumulator traffic) also flushes.
        let mut ts = TimeSeries::new(10.0, 0, 0);
        ts.roll(10.0, stats(1, 0), &[], &[]);
        let report = ts.finalize(10.0, stats(4, 1), &[], &[]);
        assert_eq!(report.windows.len(), 2);
        assert_eq!((report.windows[1].cache_hits, report.windows[1].cache_misses), (3, 1));
    }

    #[test]
    fn failovers_partition_and_fault_gauge_snapshots_at_close() {
        let mut ts = TimeSeries::new(10.0, 0, 0);
        // Window 0: two failovers, one fault goes active before close.
        ts.on_failover();
        ts.on_failover();
        ts.set_faults_active(1);
        ts.roll(10.0, stats(0, 0), &[], &[]);
        // Window 1: quiet, fault still active.
        ts.roll(20.0, stats(0, 0), &[], &[]);
        // Window 2: three failovers, the fault clears before close.
        ts.on_failover();
        ts.on_failover();
        ts.on_failover();
        ts.set_faults_active(0);
        let report = ts.finalize(30.0, stats(0, 0), &[], &[]);
        assert_eq!(report.windows.len(), 3);
        let per_window: Vec<u64> = report.windows.iter().map(|w| w.failovers).collect();
        assert_eq!(per_window, vec![2, 0, 3]);
        // Partition property: window counters sum to the run total.
        assert_eq!(per_window.iter().sum::<u64>(), 5);
        let gauges: Vec<u64> = report.windows.iter().map(|w| w.faults_active).collect();
        assert_eq!(gauges, vec![1, 1, 0]);
    }

    #[test]
    fn json_shape_is_stable_and_parseable() {
        let mut ts = TimeSeries::new(10.0, 1, 1);
        ts.on_generated();
        ts.on_completed(0.25);
        ts.on_handover();
        let g = [PoolGauge { queue_len: 1, busy_time_s: 2.0, servers: 2 }];
        let report = ts.finalize(10.0, stats(1, 1), &g, &g);
        let j = report.to_json();
        let text = j.to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).expect("self-emitted JSON parses");
        assert_eq!(parsed.get_f64("window_s").unwrap(), 10.0);
        let w = parsed.get("windows").unwrap().at(0).unwrap();
        assert_eq!(w.get_usize("completed").unwrap(), 1);
        assert_eq!(w.get("planner").unwrap().get_f64("hit_rate").unwrap(), 0.5);
        assert_eq!(w.get("latency").unwrap().get_usize("count").unwrap(), 1);
        assert_eq!(w.get("edges").unwrap().at(0).unwrap().get_usize("queue_depth").unwrap(), 1);
        // Serialisation is deterministic.
        assert_eq!(text, report.to_json().to_string_pretty());
    }
}
