//! Serving metrics: latency histogram (HDR-style log-bucketed), throughput
//! meter, windowed time series ([`timeseries`]), per-request split
//! accounting, and split-planner counters (solves / cache hits / cache
//! misses / per-reason request tallies for the fleet planner layer).

pub mod timeseries;

pub use timeseries::{
    PoolGauge, TierWindow, TimeSeries, TimeSeriesReport, WindowSummary, METRICS_SCHEMA_VERSION,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Log-bucketed latency histogram: ~2.3% relative error per bucket,
/// covering 1 µs .. ~1.2 hours in 512 buckets.
#[derive(Debug)]
pub struct Histogram {
    buckets: Mutex<HistState>,
}

#[derive(Debug)]
struct HistState {
    counts: Vec<u64>,
    total: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
    /// Samples below the 1 µs bucket floor. They still land in the edge
    /// bucket (so `total`/quantiles see them), but the clamp is counted
    /// instead of silent — a wave of sub-µs samples is a measurement
    /// bug, not a latency distribution.
    underflow: u64,
    /// Samples above the ~4470 s bucket ceiling, counted like underflow.
    overflow: u64,
}

const BUCKETS: usize = 512;
const LOG_MIN: f64 = -6.0; // 1 µs
const LOG_MAX: f64 = 3.65; // ~4470 s

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: Mutex::new(HistState {
                counts: vec![0; BUCKETS],
                total: 0,
                sum_s: 0.0,
                min_s: f64::INFINITY,
                max_s: 0.0,
                underflow: 0,
                overflow: 0,
            }),
        }
    }

    /// Unclamped bucket index — negative for sub-µs samples, `>= BUCKETS`
    /// for samples past the ceiling. `bucket_of` clamps; `record_secs`
    /// uses the raw value to count the clamp.
    fn raw_index(seconds: f64) -> isize {
        let l = seconds.max(1e-9).log10();
        ((l - LOG_MIN) / (LOG_MAX - LOG_MIN) * BUCKETS as f64) as isize
    }

    fn bucket_of(seconds: f64) -> usize {
        Self::raw_index(seconds).clamp(0, BUCKETS as isize - 1) as usize
    }

    fn bucket_value(idx: usize) -> f64 {
        let l = LOG_MIN + (idx as f64 + 0.5) / BUCKETS as f64 * (LOG_MAX - LOG_MIN);
        10f64.powf(l)
    }

    pub fn record(&self, d: Duration) {
        self.record_secs(d.as_secs_f64());
    }

    pub fn record_secs(&self, s: f64) {
        let raw = Self::raw_index(s);
        let mut st = self.buckets.lock().unwrap();
        st.counts[raw.clamp(0, BUCKETS as isize - 1) as usize] += 1;
        if raw < 0 {
            st.underflow += 1;
        } else if raw >= BUCKETS as isize {
            st.overflow += 1;
        }
        st.total += 1;
        st.sum_s += s;
        st.min_s = st.min_s.min(s);
        st.max_s = st.max_s.max(s);
    }

    /// Fold `other` into `self` without re-recording samples: bucket counts
    /// add exactly (both histograms share the fixed log-bucket layout), so
    /// quantiles of the merged histogram equal those of a histogram that
    /// had recorded every sample directly. Used to aggregate per-device
    /// histograms into fleet-wide reports (`sim::`, `coordinator::fleet`).
    ///
    /// `other` is snapshotted before `self` is locked, so concurrent merges
    /// in either direction (and self-merge, which doubles) cannot deadlock.
    pub fn merge(&self, other: &Histogram) {
        let (counts, total, sum_s, min_s, max_s, underflow, overflow) = {
            let o = other.buckets.lock().unwrap();
            (o.counts.clone(), o.total, o.sum_s, o.min_s, o.max_s, o.underflow, o.overflow)
        };
        if total == 0 {
            return;
        }
        let mut st = self.buckets.lock().unwrap();
        for (mine, theirs) in st.counts.iter_mut().zip(&counts) {
            *mine += theirs;
        }
        st.total += total;
        st.sum_s += sum_s;
        st.min_s = st.min_s.min(min_s);
        st.max_s = st.max_s.max(max_s);
        st.underflow += underflow;
        st.overflow += overflow;
    }

    pub fn count(&self) -> u64 {
        self.buckets.lock().unwrap().total
    }

    pub fn mean_s(&self) -> f64 {
        let st = self.buckets.lock().unwrap();
        if st.total == 0 {
            return 0.0;
        }
        st.sum_s / st.total as f64
    }

    pub fn min_s(&self) -> f64 {
        let st = self.buckets.lock().unwrap();
        if st.total == 0 { 0.0 } else { st.min_s }
    }

    pub fn max_s(&self) -> f64 {
        self.buckets.lock().unwrap().max_s
    }

    /// Samples that fell below the 1 µs bucket floor (clamped into the
    /// first bucket, but counted here instead of silently absorbed).
    pub fn underflow(&self) -> u64 {
        self.buckets.lock().unwrap().underflow
    }

    /// Samples past the ~4470 s bucket ceiling (clamped into the last
    /// bucket, but counted here instead of silently absorbed).
    pub fn overflow(&self) -> u64 {
        self.buckets.lock().unwrap().overflow
    }

    /// Quantile in [0,1] via bucket midpoint interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        let st = self.buckets.lock().unwrap();
        if st.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * st.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in st.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).clamp(st.min_s, st.max_s);
            }
        }
        st.max_s
    }

    /// Median latency in seconds.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th-percentile latency in seconds.
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th-percentile latency in seconds.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.count(),
            crate::util::fmt_secs(self.mean_s()),
            crate::util::fmt_secs(self.p50()),
            crate::util::fmt_secs(self.p95()),
            crate::util::fmt_secs(self.p99()),
            crate::util::fmt_secs(self.max_s()),
        );
        // Out-of-range clamps are exceptional — the tail only appears
        // when there is something to report, so the common summary
        // string stays byte-stable.
        let (uf, of) = (self.underflow(), self.overflow());
        if uf > 0 {
            s.push_str(&format!(" uf={uf}"));
        }
        if of > 0 {
            s.push_str(&format!(" of={of}"));
        }
        s
    }
}

/// Number of request-reason counter slots (one per
/// `planner::ReplanReason` variant; see
/// [`PlannerStats::requests_by_reason`]).
pub const REPLAN_REASONS: usize = 5;

/// Split-planner accounting: how many full optimiser solves actually ran
/// versus how many decisions the plan cache served, plus a per-reason
/// request tally (spawn / drift / band crossing / migration /
/// failover). Atomic so
/// the parallel re-solve fan-out ([`crate::optimizer::cache`],
/// `sim::on_reoptimize`) can record from worker threads.
#[derive(Debug, Default)]
pub struct PlannerCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    solves: AtomicU64,
    /// Requests per replan reason, indexed by
    /// `planner::ReplanReason::index()` (this module stays
    /// reason-agnostic: the façade passes the slot).
    reasons: [AtomicU64; REPLAN_REASONS],
}

/// One consistent snapshot of [`PlannerCounters`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannerStats {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub solves: u64,
    /// Planner requests per replan reason, indexed by
    /// `planner::ReplanReason::index()`:
    /// `[spawn, drift, band, migration, failover]`. This is how
    /// migration re-solves (edge handover) and fault-driven failover
    /// re-solves are accounted distinctly from battery-band and drift
    /// re-splits.
    pub requests_by_reason: [u64; REPLAN_REASONS],
}

impl PlannerStats {
    /// Fraction of decisions served from cache, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / total as f64
    }

    /// Requests prompted by an edge handover
    /// ([`crate::planner::ReplanReason::Migration`]).
    pub fn migration_requests(&self) -> u64 {
        self.requests_by_reason[crate::planner::ReplanReason::Migration.index()]
    }

    /// Requests prompted by an injected fault
    /// ([`crate::planner::ReplanReason::Failover`]).
    pub fn failover_requests(&self) -> u64 {
        self.requests_by_reason[crate::planner::ReplanReason::Failover.index()]
    }
}

impl PlannerCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::SeqCst);
    }

    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::SeqCst);
    }

    /// A full optimiser run actually executed (cached or not).
    pub fn record_solve(&self) {
        self.solves.fetch_add(1, Ordering::SeqCst);
    }

    /// A planner request arrived for reason slot `idx`
    /// (`planner::ReplanReason::index()`). An out-of-range slot — a
    /// `ReplanReason` variant added without bumping [`REPLAN_REASONS`]
    /// — panics loudly rather than silently folding into another
    /// reason's tally.
    pub fn record_reason(&self, idx: usize) {
        self.reasons[idx].fetch_add(1, Ordering::SeqCst);
    }

    pub fn snapshot(&self) -> PlannerStats {
        let mut requests_by_reason = [0u64; REPLAN_REASONS];
        for (slot, a) in requests_by_reason.iter_mut().zip(&self.reasons) {
            *slot = a.load(Ordering::SeqCst);
        }
        PlannerStats {
            cache_hits: self.hits.load(Ordering::SeqCst),
            cache_misses: self.misses.load(Ordering::SeqCst),
            solves: self.solves.load(Ordering::SeqCst),
            requests_by_reason,
        }
    }
}

/// Requests-per-second meter over the whole run.
///
/// Two clock disciplines share one meter:
///
/// * **wall clock** (default, [`ThroughputMeter::new`]) — `elapsed()` is
///   real `Instant` time, for the live serving paths;
/// * **virtual clock** ([`ThroughputMeter::virtual_time`] /
///   [`ThroughputMeter::set_elapsed_s`]) — `elapsed()`/`rps()` read a
///   caller-supplied elapsed-seconds override, so a simulated run's
///   throughput is a pure function of its virtual horizon and therefore
///   deterministic across machines and repeat runs.
///
/// The counter is a plain [`AtomicU64`]: `record` from any worker thread
/// is one uncontended `fetch_add`, no lock. All atomics here use
/// `SeqCst` — these counters land in serialized reports, and detlint
/// rule D4 bans relaxed orderings on the export plane.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    completed: AtomicU64,
    /// f64 bit pattern of the virtual elapsed override; `u64::MAX` (an
    /// f64 NaN) is the sentinel for "no override — use the wall clock".
    elapsed_bits: AtomicU64,
}

/// Sentinel bit pattern meaning "no virtual override" (a NaN, so it can
/// never collide with a legitimate `f64::to_bits` of an elapsed time).
const WALL_CLOCK: u64 = u64::MAX;

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter {
            // detlint:allow(D1): wall-clock discipline for live serving; sim paths pin the virtual override
            start: Instant::now(),
            completed: AtomicU64::new(0),
            elapsed_bits: AtomicU64::new(WALL_CLOCK),
        }
    }

    /// A meter that reports `elapsed_s` of virtual time instead of wall
    /// clock (the override can be re-pinned later with
    /// [`ThroughputMeter::set_elapsed_s`] as the virtual clock advances).
    pub fn virtual_time(elapsed_s: f64) -> Self {
        let m = Self::new();
        m.set_elapsed_s(elapsed_s);
        m
    }

    /// Pin the elapsed time to `s` seconds of virtual time. From here on
    /// `elapsed()`/`rps()` are deterministic functions of the recorded
    /// count and this value.
    pub fn set_elapsed_s(&self, s: f64) {
        self.elapsed_bits.store(s.to_bits(), Ordering::SeqCst);
    }

    pub fn record(&self, n: u64) {
        self.completed.fetch_add(n, Ordering::SeqCst);
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::SeqCst)
    }

    /// Elapsed seconds: the virtual override if pinned, wall clock
    /// otherwise.
    pub fn elapsed_s(&self) -> f64 {
        match self.elapsed_bits.load(Ordering::SeqCst) {
            WALL_CLOCK => self.start.elapsed().as_secs_f64(),
            bits => f64::from_bits(bits),
        }
    }

    pub fn elapsed(&self) -> Duration {
        Duration::from_secs_f64(self.elapsed_s().max(0.0))
    }

    pub fn rps(&self) -> f64 {
        let e = self.elapsed_s();
        if e <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let h = Histogram::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 100.0] {
            h.record_secs(ms / 1000.0);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_s() - 0.022).abs() < 1e-9);
        assert!((h.min_s() - 0.001).abs() < 1e-9);
        assert!((h.max_s() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn quantiles_ordered_and_within_range() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record_secs(i as f64 / 1000.0);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!((p50 - 0.5).abs() / 0.5 < 0.05, "p50={p50}");
        assert!((p99 - 0.99).abs() / 0.99 < 0.05, "p99={p99}");
        // The named helpers are exactly the quantiles.
        assert_eq!(h.p50(), p50);
        assert_eq!(h.p95(), p95);
        assert_eq!(h.p99(), p99);
    }

    #[test]
    fn bucket_relative_error_bounded() {
        // Round-trip value -> bucket -> midpoint stays within ~3%.
        for v in [1e-5, 1e-3, 0.1, 1.0, 10.0, 100.0] {
            let mid = Histogram::bucket_value(Histogram::bucket_of(v));
            assert!((mid - v).abs() / v < 0.03, "v={v} mid={mid}");
        }
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn merge_preserves_count_sum_min_max() {
        let a = Histogram::new();
        let b = Histogram::new();
        for ms in [1.0, 5.0, 20.0] {
            a.record_secs(ms / 1000.0);
        }
        for ms in [0.5, 300.0] {
            b.record_secs(ms / 1000.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert!((a.mean_s() - (1.0 + 5.0 + 20.0 + 0.5 + 300.0) / 5000.0).abs() < 1e-12);
        assert!((a.min_s() - 0.0005).abs() < 1e-12);
        assert!((a.max_s() - 0.3).abs() < 1e-12);
        // b is untouched.
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn merge_equals_direct_recording() {
        // Bucket-count invariant: merging shards must yield exactly the
        // quantiles of one histogram that saw every sample.
        let direct = Histogram::new();
        let shard_a = Histogram::new();
        let shard_b = Histogram::new();
        for i in 1..=1000u32 {
            let s = i as f64 / 250.0;
            direct.record_secs(s);
            let shard = if i % 2 == 0 { &shard_a } else { &shard_b };
            shard.record_secs(s);
        }
        let merged = Histogram::new();
        merged.merge(&shard_a);
        merged.merge(&shard_b);
        assert_eq!(merged.count(), direct.count());
        assert_eq!(merged.min_s(), direct.min_s());
        assert_eq!(merged.max_s(), direct.max_s());
        assert!((merged.mean_s() - direct.mean_s()).abs() < 1e-12);
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), direct.quantile(q), "q={q}");
        }
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = Histogram::new();
        a.record_secs(0.25);
        a.merge(&Histogram::new());
        assert_eq!(a.count(), 1);
        assert_eq!(a.min_s(), 0.25);
        let empty = Histogram::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 1);
        assert_eq!(empty.min_s(), 0.25);
        assert_eq!(empty.max_s(), 0.25);
    }

    #[test]
    fn planner_counters_snapshot_and_hit_rate() {
        let c = PlannerCounters::new();
        assert_eq!(c.snapshot().hit_rate(), 0.0);
        for _ in 0..3 {
            c.record_hit();
        }
        c.record_miss();
        c.record_solve();
        let s = c.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses, s.solves), (3, 1, 1));
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.requests_by_reason, [0; REPLAN_REASONS]);
    }

    #[test]
    fn planner_counters_tally_requests_per_reason_slot() {
        let c = PlannerCounters::new();
        c.record_reason(0); // spawn
        c.record_reason(0);
        c.record_reason(1); // drift
        c.record_reason(3); // migration
        c.record_reason(4); // failover
        let s = c.snapshot();
        assert_eq!(s.requests_by_reason, [2, 1, 0, 1, 1]);
        assert_eq!(s.migration_requests(), 1);
        assert_eq!(s.failover_requests(), 1);
    }

    #[test]
    fn throughput_meter_counts() {
        let t = ThroughputMeter::new();
        t.record(10);
        t.record(5);
        assert_eq!(t.completed(), 15);
        std::thread::sleep(Duration::from_millis(20));
        assert!(t.rps() > 0.0);
    }

    #[test]
    fn throughput_meter_virtual_override_is_deterministic() {
        let t = ThroughputMeter::virtual_time(120.0);
        t.record(600);
        assert_eq!(t.elapsed_s(), 120.0);
        assert_eq!(t.rps(), 5.0);
        assert_eq!(t.elapsed(), Duration::from_secs(120));
        // Re-pinning moves the rate with it.
        t.set_elapsed_s(300.0);
        assert_eq!(t.rps(), 2.0);
        // Zero virtual elapsed never divides by zero.
        t.set_elapsed_s(0.0);
        assert_eq!(t.rps(), 0.0);
    }

    #[test]
    fn throughput_meter_records_from_many_threads() {
        let t = std::sync::Arc::new(ThroughputMeter::virtual_time(10.0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let t = std::sync::Arc::clone(&t);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        t.record(1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.completed(), 4000);
        assert_eq!(t.rps(), 400.0);
    }

    #[test]
    fn histogram_counts_underflow_and_overflow() {
        let h = Histogram::new();
        h.record_secs(1e-8); // below the 1 µs floor
        h.record_secs(0.5); // in range
        h.record_secs(10_000.0); // above the ~4470 s ceiling
        assert_eq!(h.count(), 3, "clamped samples still count toward total");
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        let s = h.summary();
        assert!(s.contains(" uf=1") && s.contains(" of=1"), "summary hides clamps: {s}");
        // An in-range histogram keeps the legacy summary shape.
        let clean = Histogram::new();
        clean.record_secs(0.5);
        let s = clean.summary();
        assert!(!s.contains("uf=") && !s.contains("of="), "spurious clamp tail: {s}");
        assert_eq!((clean.underflow(), clean.overflow()), (0, 0));
    }

    #[test]
    fn merge_carries_underflow_and_overflow() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_secs(1e-9);
        b.record_secs(5000.0);
        b.record_secs(1e-12);
        a.merge(&b);
        assert_eq!(a.underflow(), 2);
        assert_eq!(a.overflow(), 1);
        // b untouched.
        assert_eq!((b.underflow(), b.overflow()), (1, 1));
    }
}
