//! The answer side of the planning façade: [`PlanOutcome`] — the
//! universal decision value plus everything a consumer might want to
//! know about how it was reached.

use crate::edge::SplitPlan;
use crate::optimizer::{PlanKey, PlannerKind};

use super::request::{ReplanReason, Strategy};

/// How the plan was served relative to the planner's memo table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Served from the split-plan cache (no solve ran).
    Hit,
    /// Solved (inline or presolved) and cached for the next request.
    Miss,
    /// Cache disabled for this request (planner config, or an
    /// independent-run request) — every call solves.
    Bypassed,
}

/// Where a decision came from: the strategy and cache-key kind it was
/// planned under, whether the cache served it, and the derived solve
/// seed — enough to reproduce the exact solve offline.
#[derive(Clone, Debug)]
pub struct Provenance {
    pub strategy: Strategy,
    /// Cache-key tag ([`PlanKey::kind`]) the decision was stored under.
    pub kind: PlannerKind,
    pub cache: CacheOutcome,
    /// Why the consumer asked (spawn / drift / band crossing /
    /// migration) — copied from the request, never part of the key:
    /// a migration re-solve landing on an already-planned state is a
    /// [`CacheOutcome::Hit`] on purpose.
    pub reason: ReplanReason,
    /// The full quantised planner state this decision keys on.
    pub key: PlanKey,
    /// The seed the solve ran with (key-derived in fleet configs, the
    /// configured seed in paper-exhibit configs; mixed per
    /// [`crate::planner::PlanRequest::run`]).
    pub derived_seed: u64,
    /// Bandwidth actually fed to the §III models, after bucketing.
    pub quantized_bw_mbps: f64,
    /// NSGA-II objective evaluations, when this call ran the solver
    /// inline (0 for cache hits, presolved misses, and non-GA
    /// strategies).
    pub evaluations: u64,
}

/// The universal planning answer: one `(l1, l2)` plan (two-tier plans
/// have `l2 == l1`), its predicted objectives, the Pareto-front
/// summary when this call computed one, and full provenance.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    /// The chosen split; `None` when the strategy found no feasible
    /// split (e.g. an infeasible ε box, or a hopeless device state).
    pub plan: Option<SplitPlan>,
    /// Predicted §III objectives `[f1 latency s, f2 energy J, f3
    /// memory bytes]` of `plan`, evaluated at the quantised bandwidth
    /// (`None` iff `plan` is `None`).
    pub objectives: Option<[f64; 3]>,
    /// Pareto-front summary (plan, raw objectives). `Some` only when
    /// this call ran a front-producing solve inline — SmartSplit /
    /// Topsis on a cache miss or bypass. Cache hits and point
    /// strategies return `None`; the provenance says which happened.
    pub pareto: Option<Vec<(SplitPlan, [f64; 3])>>,
    pub provenance: Provenance,
}

impl PlanOutcome {
    /// The chosen split (shorthand for `.plan`).
    pub fn split(&self) -> Option<SplitPlan> {
        self.plan
    }

    /// The device-side depth of the chosen split, if any.
    pub fn l1(&self) -> Option<usize> {
        self.plan.map(|p| p.l1)
    }
}
