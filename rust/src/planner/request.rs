//! The request side of the planning façade: [`Strategy`] — every
//! decision procedure the repo knows behind one name — and
//! [`PlanRequest`], the single shape every consumer asks in.

use std::sync::Arc;

use crate::coordinator::battery::BatteryBand;
use crate::device::ComputeProfile;
use crate::edge::EdgeSite;
use crate::models::ModelProfile;
use crate::optimizer::{Algorithm, PlannerKind};

/// Every splitting decision procedure in the repo, behind one name:
/// the paper's Algorithm 1, the exhaustive-front variant the fleet
/// runs at city scale, the five §VI-C baselines, and the §V-A
/// scalarisation methods the paper argues NSGA-II against.
///
/// `Strategy` is deliberately a *parameter-free* enum (`Copy + Eq +
/// Hash`): each variant names a fully specified procedure, so a
/// strategy can sit inside a [`crate::optimizer::PlanKey`] and two
/// requests that quantise to the same key are guaranteed to mean the
/// same solve. The scalarisation variants therefore fix their knobs to
/// documented defaults ([`Strategy::SCALAR_WEIGHTS`],
/// [`Strategy::METRIC_ORDER`], [`Strategy::EPSILON_CEILINGS`]); callers
/// who need custom weights use [`crate::optimizer::scalarization`]
/// directly — those are evaluation primitives, not fleet strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Full Algorithm 1: NSGA-II Pareto set → battery-band-weighted
    /// TOPSIS. 2-D `(l1, l2)` genome under an edge tier.
    SmartSplit,
    /// Exhaustive true Pareto front → battery-band-weighted TOPSIS.
    /// O(L) per decision (O(L²) tiered) — the city-scale default.
    Topsis,
    /// Latency-based optimisation: argmin f1 (§VI-C).
    Lbo,
    /// Energy-based optimisation: argmin f2 (§VI-C).
    Ebo,
    /// CNN on smartphone: every layer on the device (§VI-C).
    Cos,
    /// CNN on cloud: `l1 = 0`, the raw input is uploaded (§VI-C).
    Coc,
    /// Random split, uniform over `1..=L-1`, seeded like every other
    /// strategy (same request ⇒ same "random" split; vary
    /// [`PlanRequest::run`] to draw independent samples).
    Rs,
    /// Weighted-sum scalarisation (§V-A, [50]) at
    /// [`Strategy::SCALAR_WEIGHTS`].
    WeightedSum,
    /// Weighted-metric / compromise programming (§V-A, [51]) at
    /// [`Strategy::SCALAR_WEIGHTS`], order [`Strategy::METRIC_ORDER`].
    WeightedMetric,
    /// ε-constrained optimisation (§V-A, [49]): minimise latency
    /// subject to [`Strategy::EPSILON_CEILINGS`] on normalised energy
    /// and memory. The ε box can be infeasible — the practical weakness
    /// the paper alludes to — in which case the outcome carries no plan.
    EpsilonConstrained,
}

impl Strategy {
    /// Normalised-objective weights used by [`Strategy::WeightedSum`]
    /// and [`Strategy::WeightedMetric`] (equal emphasis, the paper's
    /// Eq. 15 stance).
    pub const SCALAR_WEIGHTS: [f64; 3] = [1.0, 1.0, 1.0];
    /// Metric order `p` of [`Strategy::WeightedMetric`] (Euclidean).
    pub const METRIC_ORDER: f64 = 2.0;
    /// Primary objective of [`Strategy::EpsilonConstrained`] (f1).
    pub const EPSILON_PRIMARY: usize = 0;
    /// Normalised ceilings of [`Strategy::EpsilonConstrained`]:
    /// latency free, energy and memory each capped at 0.75.
    pub const EPSILON_CEILINGS: [f64; 3] = [1.0, 0.75, 0.75];

    pub const ALL: [Strategy; 10] = [
        Strategy::SmartSplit,
        Strategy::Topsis,
        Strategy::Lbo,
        Strategy::Ebo,
        Strategy::Cos,
        Strategy::Coc,
        Strategy::Rs,
        Strategy::WeightedSum,
        Strategy::WeightedMetric,
        Strategy::EpsilonConstrained,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::SmartSplit => "SmartSplit",
            Strategy::Topsis => "Topsis",
            Strategy::Lbo => "LBO",
            Strategy::Ebo => "EBO",
            Strategy::Cos => "COS",
            Strategy::Coc => "COC",
            Strategy::Rs => "RS",
            Strategy::WeightedSum => "WeightedSum",
            Strategy::WeightedMetric => "WeightedMetric",
            Strategy::EpsilonConstrained => "EpsilonConstrained",
        }
    }

    /// Case-insensitive lookup; the error lists every valid name (the
    /// single `--planner` parse in [`crate::util::cli`] surfaces it
    /// verbatim).
    pub fn by_name(name: &str) -> Result<Strategy, String> {
        Self::ALL
            .iter()
            .copied()
            .find(|s| s.name().eq_ignore_ascii_case(name))
            .ok_or_else(|| {
                let names: Vec<&str> = Self::ALL.iter().map(|s| s.name()).collect();
                format!("unknown strategy {name:?} (valid: {})", names.join(", "))
            })
    }

    /// The cache-key tag this strategy plans under (part of
    /// [`crate::optimizer::PlanKey`]; distinct strategies never share a
    /// cached plan).
    pub fn kind(&self) -> PlannerKind {
        match self {
            Strategy::SmartSplit => PlannerKind::SmartSplit,
            Strategy::Topsis => PlannerKind::Topsis,
            Strategy::Lbo => PlannerKind::Lbo,
            Strategy::Ebo => PlannerKind::Ebo,
            Strategy::Cos => PlannerKind::Cos,
            Strategy::Coc => PlannerKind::Coc,
            Strategy::Rs => PlannerKind::Rs,
            Strategy::WeightedSum => PlannerKind::WeightedSum,
            Strategy::WeightedMetric => PlannerKind::WeightedMetric,
            Strategy::EpsilonConstrained => PlannerKind::EpsilonConstrained,
        }
    }
}

impl From<Algorithm> for Strategy {
    /// The §VI-C comparison set embeds in the strategy space.
    fn from(a: Algorithm) -> Strategy {
        match a {
            Algorithm::SmartSplit => Strategy::SmartSplit,
            Algorithm::Lbo => Strategy::Lbo,
            Algorithm::Ebo => Strategy::Ebo,
            Algorithm::Cos => Strategy::Cos,
            Algorithm::Coc => Strategy::Coc,
            Algorithm::Rs => Strategy::Rs,
        }
    }
}

/// Why a consumer is asking for a (re-)plan. **Provenance, not planner
/// state**: the reason is deliberately *not* part of the
/// [`crate::optimizer::PlanKey`] — two devices in the same quantised
/// state must share one cached plan whatever prompted the ask, so a
/// migration re-solve that lands on an already-planned `(state, site)`
/// key is a cache hit, not a fresh solve. Requests are tallied per
/// reason in [`crate::metrics::PlannerCounters`] (surfaced as
/// [`crate::metrics::PlannerStats::requests_by_reason`], indexed by
/// [`ReplanReason::index`]), which is how migration re-solves are
/// accounted distinctly from battery-band re-splits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReplanReason {
    /// First plan of a device's life (spawn, fleet start, a one-shot
    /// `optimize` call). The default.
    Spawn,
    /// Periodic re-optimisation sweep: link bandwidth or battery band
    /// drifted past the threshold.
    Drift,
    /// Event-driven battery trigger: a request's drain crossed a
    /// [`BatteryBand`] boundary.
    BandCrossing,
    /// Edge handover: the device re-attached to a different site and
    /// re-plans with the new [`TierContext`].
    Migration,
    /// Fault recovery: the device was forced off (or back onto) a site
    /// by an injected fault — an outage-driven reattach storm or a
    /// backhaul brownout/restore — and re-plans with the new
    /// [`TierContext`]. Accounted distinctly from voluntary
    /// [`ReplanReason::Migration`] so failure scenarios are auditable
    /// in the per-reason tallies.
    Failover,
}

impl ReplanReason {
    pub const ALL: [ReplanReason; 5] = [
        ReplanReason::Spawn,
        ReplanReason::Drift,
        ReplanReason::BandCrossing,
        ReplanReason::Migration,
        ReplanReason::Failover,
    ];

    /// Stable slot in [`crate::metrics::PlannerStats::requests_by_reason`].
    pub fn index(self) -> usize {
        match self {
            ReplanReason::Spawn => 0,
            ReplanReason::Drift => 1,
            ReplanReason::BandCrossing => 2,
            ReplanReason::Migration => 3,
            ReplanReason::Failover => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ReplanReason::Spawn => "spawn",
            ReplanReason::Drift => "drift",
            ReplanReason::BandCrossing => "band",
            ReplanReason::Migration => "migration",
            ReplanReason::Failover => "failover",
        }
    }
}

/// The edge-tier context of a request: which site the device is
/// assigned to and everything about that site a tiered solve depends
/// on. `None` in the request plans the paper's two-tier split — the
/// degenerate case of the same request shape.
#[derive(Clone, Copy, Debug)]
pub struct TierContext {
    /// Index of the assigned site in the run's
    /// [`crate::edge::EdgeTopology`] (part of the planner state: sites
    /// are independently reconfigurable).
    pub site: usize,
    /// The site itself: torso pool size, server profile, backhaul.
    pub edge: EdgeSite,
}

/// Everything a split decision depends on — the one request shape
/// every consumer (sim, fleet, coordinator, figures, CLI, benches)
/// asks in.
///
/// The [`crate::planner::Planner`] quantises this to a
/// [`crate::optimizer::PlanKey`] (bandwidth bucketing per its config),
/// derives the solve seed from that key, and serves the decision
/// through its plan cache — so two requests that quantise identically
/// share one solve, on any thread, in any order.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    /// The model being split (shared with pool workers during batch
    /// presolves, hence `Arc`).
    pub model: Arc<ModelProfile>,
    /// Device compute profile (must carry a radio).
    pub profile: &'static ComputeProfile,
    /// Battery band the decision should weight energy for.
    pub band: BatteryBand,
    /// Exact device↔cloud link bandwidth in Mbps (the planner buckets
    /// it per its configured ratio before solving).
    pub bandwidth_mbps: f64,
    /// Edge-tier context; `None` is the paper's two-tier split.
    pub tier: Option<TierContext>,
    pub strategy: Strategy,
    /// Independent-run index: `0` (the default) is the canonical
    /// cached decision; any other value derives an independent solve
    /// seed and bypasses the cache — how the paper exhibits average
    /// [`Strategy::Rs`] over N runs.
    pub run: u64,
    /// Why this plan is being asked for — provenance and accounting
    /// only, never part of the cache key (see [`ReplanReason`]).
    pub reason: ReplanReason,
}

impl PlanRequest {
    /// Canonical two-tier request (run 0, no edge context).
    pub fn two_tier(
        model: Arc<ModelProfile>,
        profile: &'static ComputeProfile,
        band: BatteryBand,
        bandwidth_mbps: f64,
        strategy: Strategy,
    ) -> PlanRequest {
        PlanRequest {
            model,
            profile,
            band,
            bandwidth_mbps,
            tier: None,
            strategy,
            run: 0,
            reason: ReplanReason::Spawn,
        }
    }

    /// This request planned against an edge site.
    pub fn with_tier(mut self, site: usize, edge: EdgeSite) -> PlanRequest {
        self.tier = Some(TierContext { site, edge });
        self
    }

    /// This request as independent run `run` (see [`PlanRequest::run`]).
    pub fn with_run(mut self, run: u64) -> PlanRequest {
        self.run = run;
        self
    }

    /// This request tagged with why it is being asked (see
    /// [`ReplanReason`] — provenance only, never the cache key).
    pub fn with_reason(mut self, reason: ReplanReason) -> PlanRequest {
        self.reason = reason;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_case_insensitively() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::by_name(s.name()), Ok(s));
            assert_eq!(Strategy::by_name(&s.name().to_lowercase()), Ok(s));
            assert_eq!(Strategy::by_name(&s.name().to_uppercase()), Ok(s));
        }
    }

    #[test]
    fn unknown_name_lists_every_strategy() {
        let err = Strategy::by_name("nope").unwrap_err();
        for s in Strategy::ALL {
            assert!(err.contains(s.name()), "error {err:?} misses {}", s.name());
        }
    }

    #[test]
    fn kinds_are_distinct_per_strategy() {
        let kinds: std::collections::HashSet<PlannerKind> =
            Strategy::ALL.iter().map(|s| s.kind()).collect();
        assert_eq!(kinds.len(), Strategy::ALL.len());
    }

    #[test]
    fn algorithm_embedding_preserves_names() {
        for a in Algorithm::ALL {
            assert_eq!(Strategy::from(a).name(), a.name());
        }
    }

    #[test]
    fn replan_reasons_index_their_counter_slots_bijectively() {
        // The metrics module sizes its per-reason counter array from
        // REPLAN_REASONS; a variant added here without bumping it would
        // panic at the first record — this pins the two in lockstep.
        assert_eq!(ReplanReason::ALL.len(), crate::metrics::REPLAN_REASONS);
        let idx: std::collections::HashSet<usize> =
            ReplanReason::ALL.iter().map(|r| r.index()).collect();
        assert_eq!(idx.len(), ReplanReason::ALL.len());
        for r in ReplanReason::ALL {
            assert!(r.index() < ReplanReason::ALL.len(), "{:?} indexes out of range", r);
            assert_eq!(ReplanReason::ALL[r.index()], r, "ALL must be index-ordered");
        }
    }
}
