//! The one solve code path behind [`crate::planner::Planner`]: every
//! strategy, flat or tiered, funnels through [`solve_quantised`] — the
//! two-tier request is just the degenerate case with no site context.
//!
//! Migration invariant (pinned by `tests/planner_parity.rs`): for the
//! pre-façade strategies the decisions here are byte-identical to the
//! frozen entry points they replace — `SmartSplit` reproduces
//! [`crate::optimizer::smartsplit_banded`] /
//! [`crate::edge::tiered_smartsplit_banded`], `Topsis` reproduces
//! [`crate::coordinator::battery::battery_aware_split_banded`] /
//! [`crate::edge::tiered_split_banded`], and the §VI-C / §V-A
//! strategies reproduce their [`crate::optimizer`] free functions on
//! the flat domain. The same selection rules run over the tiered
//! `(l1, l2)` triangle, which the old free functions never supported.

use crate::coordinator::battery::BatteryBand;
use crate::device::ComputeProfile;
use crate::edge::{BackhaulLink, EdgeSite, SplitPlan, TieredPerfModel, TieredSplitProblem};
use crate::models::ModelProfile;
use crate::optimizer::cache::with_fleet_solver;
use crate::optimizer::{
    exhaustive_pareto_front, member_perf_model, rs, topsis, Nsga2Params, SplitProblem,
};
use crate::perfmodel::PerfModel;
use crate::util::rng::Xoshiro256;

use super::request::Strategy;

/// Result of one solve: the plan, the Pareto front when the strategy
/// computed one, and the NSGA-II evaluation count when the GA ran.
pub(crate) struct Solved {
    pub plan: Option<SplitPlan>,
    pub front: Option<Vec<(SplitPlan, [f64; 3])>>,
    pub evaluations: u64,
}

impl Solved {
    fn none() -> Solved {
        Solved { plan: None, front: None, evaluations: 0 }
    }

    fn point(plan: SplitPlan) -> Solved {
        Solved { plan: Some(plan), front: None, evaluations: 0 }
    }
}

/// Run `strategy` for one quantised planner state. A pure function of
/// its arguments (the seed is key-derived by the caller), shared by the
/// inline and pool-worker paths so scheduling cannot change any
/// decision; quantisation happened before this call, in cached and
/// uncached paths alike. `site` carries the assigned edge site with its
/// already-bucketed backhaul bandwidth; `None` plans the two-tier
/// split.
pub(crate) fn solve_quantised(
    strategy: Strategy,
    profile: &'static ComputeProfile,
    model: &ModelProfile,
    bw_q: f64,
    band: BatteryBand,
    site: Option<(EdgeSite, f64)>,
    params: &Nsga2Params,
    seed: u64,
) -> Solved {
    let pm = member_perf_model(profile, model, bw_q);
    match site {
        None => solve_flat(strategy, &pm, band, params, seed),
        Some((s, backhaul_q)) => {
            let backhaul =
                BackhaulLink { bandwidth_mbps: backhaul_q, latency_s: s.backhaul.latency_s };
            let tpm = TieredPerfModel::new(pm, s.profile, s.servers, backhaul);
            solve_tiered(strategy, &tpm, band, params, seed)
        }
    }
}

/// Predicted objectives of an adopted plan under the same quantised
/// state it was solved in (what [`crate::planner::PlanOutcome`]
/// reports). Total over the whole embedded plan space, COC (`l1 == 0`)
/// included — the tiered tables charge its input relay across the
/// backhaul exactly as the simulator does.
pub(crate) fn objectives_of(
    profile: &'static ComputeProfile,
    model: &ModelProfile,
    bw_q: f64,
    site: Option<(EdgeSite, f64)>,
    plan: SplitPlan,
) -> [f64; 3] {
    let pm = member_perf_model(profile, model, bw_q);
    match site {
        None => pm.objectives(plan.l1),
        Some((s, backhaul_q)) => {
            let backhaul =
                BackhaulLink { bandwidth_mbps: backhaul_q, latency_s: s.backhaul.latency_s };
            TieredPerfModel::new(pm, s.profile, s.servers, backhaul).objectives(plan)
        }
    }
}

/// Band-weighted TOPSIS over `(plan, raw objectives)` rows — the shared
/// choice stage of every Pareto strategy. Scaling the f2 column before
/// vector normalisation acts exactly like a TOPSIS attribute weight.
fn banded_topsis(
    front: &[(SplitPlan, [f64; 3])],
    feasible: &[bool],
    band: BatteryBand,
) -> Option<SplitPlan> {
    if front.is_empty() {
        return None;
    }
    let w = band.energy_weight();
    let rows: Vec<Vec<f64>> =
        front.iter().map(|(_, o)| vec![o[0], o[1] * w, o[2]]).collect();
    topsis(&rows, feasible).map(|r| front[r.chosen].0)
}

fn solve_flat(
    strategy: Strategy,
    pm: &PerfModel<'_>,
    band: BatteryBand,
    params: &Nsga2Params,
    seed: u64,
) -> Solved {
    let l = pm.profile.num_layers;
    match strategy {
        Strategy::SmartSplit => {
            let problem = SplitProblem::new(pm);
            let set = with_fleet_solver(|s| {
                s.solve(&problem, &Nsga2Params { seed, ..params.clone() })
            });
            let front: Vec<(SplitPlan, [f64; 3])> = set
                .members
                .iter()
                .map(|m| {
                    let l1 = m.genome[0] as usize;
                    (SplitPlan::two_tier(l1), problem.objectives_at(l1))
                })
                .collect();
            let feasible: Vec<bool> =
                front.iter().map(|(p, _)| problem.feasible_at(p.l1)).collect();
            let plan = banded_topsis(&front, &feasible, band);
            Solved { plan, front: Some(front), evaluations: set.evaluations }
        }
        Strategy::Topsis => {
            let front: Vec<(SplitPlan, [f64; 3])> = exhaustive_pareto_front(pm)
                .into_iter()
                .map(|l1| (SplitPlan::two_tier(l1), pm.objectives(l1)))
                .collect();
            let feasible = vec![true; front.len()];
            let plan = banded_topsis(&front, &feasible, band);
            Solved { plan, front: Some(front), evaluations: 0 }
        }
        Strategy::Cos => Solved::point(SplitPlan::two_tier(l)),
        Strategy::Coc => Solved::point(SplitPlan::two_tier(0)),
        Strategy::Rs => {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            Solved::point(SplitPlan::two_tier(rs(pm, &mut rng).l1))
        }
        // The selection-rule strategies share one enumerated domain.
        _ => Candidates::flat(pm).select(strategy),
    }
}

fn solve_tiered(
    strategy: Strategy,
    tpm: &TieredPerfModel<'_>,
    band: BatteryBand,
    params: &Nsga2Params,
    seed: u64,
) -> Solved {
    let l = tpm.num_layers();
    match strategy {
        Strategy::SmartSplit => {
            let problem = TieredSplitProblem::new(tpm);
            let set = with_fleet_solver(|s| {
                s.solve(&problem, &Nsga2Params { seed, ..params.clone() })
            });
            let front: Vec<(SplitPlan, [f64; 3])> = set
                .members
                .iter()
                .map(|m| {
                    let p = SplitPlan { l1: m.genome[0] as usize, l2: m.genome[1] as usize };
                    (p, problem.objectives_at(p))
                })
                .collect();
            let feasible: Vec<bool> =
                front.iter().map(|(p, _)| problem.feasible_at(*p)).collect();
            let plan = banded_topsis(&front, &feasible, band);
            Solved { plan, front: Some(front), evaluations: set.evaluations }
        }
        Strategy::Topsis => {
            let front: Vec<(SplitPlan, [f64; 3])> = crate::edge::exhaustive_tiered_front(tpm)
                .into_iter()
                .map(|p| (p, tpm.objectives(p)))
                .collect();
            let feasible = vec![true; front.len()];
            let plan = banded_topsis(&front, &feasible, band);
            Solved { plan, front: Some(front), evaluations: 0 }
        }
        // The paper's extremes embed unchanged: COS keeps everything on
        // the device, COC ships the raw input through to the cloud
        // (empty torso either way).
        Strategy::Cos => Solved::point(SplitPlan { l1: l, l2: l }),
        Strategy::Coc => Solved::point(SplitPlan { l1: 0, l2: 0 }),
        Strategy::Rs => {
            // The paper defines RS on the single split point; under a
            // tier it stays a two-tier draw (no random torso).
            let mut rng = Xoshiro256::seed_from_u64(seed);
            Solved::point(SplitPlan::two_tier(rs(&tpm.device, &mut rng).l1))
        }
        _ => Candidates::tiered(tpm).select(strategy),
    }
}

/// The enumerated feasible decision domain with its raw objectives —
/// `(1..L)` two-tier splits for a flat request (exactly the domain of
/// [`crate::optimizer::scalarization`]), the feasible `(l1, l2)`
/// triangle of [`TieredSplitProblem`] for a tiered one. The selection
/// rules below are domain-agnostic, which is what lets LBO/EBO and the
/// scalarisation methods run under an edge tier at all.
struct Candidates {
    plans: Vec<SplitPlan>,
    objs: Vec<[f64; 3]>,
}

impl Candidates {
    fn flat(pm: &PerfModel<'_>) -> Candidates {
        let l = pm.profile.num_layers;
        let plans: Vec<SplitPlan> =
            (1..l).filter(|&i| pm.feasible(i)).map(SplitPlan::two_tier).collect();
        let objs = plans.iter().map(|p| pm.objectives(p.l1)).collect();
        Candidates { plans, objs }
    }

    fn tiered(tpm: &TieredPerfModel<'_>) -> Candidates {
        let l = tpm.num_layers();
        let mut plans = Vec::new();
        for l1 in 1..=l {
            for l2 in l1..=l {
                let p = SplitPlan { l1, l2 };
                if tpm.feasible(p) {
                    plans.push(p);
                }
            }
        }
        let objs = plans.iter().map(|&p| tpm.objectives(p)).collect();
        Candidates { plans, objs }
    }

    /// Min-max normalised objective rows (the §V-A methods operate on
    /// normalised columns; same formula as
    /// [`crate::optimizer::scalarization`]).
    fn normalised(&self) -> Vec<[f64; 3]> {
        let mut lo = [f64::INFINITY; 3];
        let mut hi = [f64::NEG_INFINITY; 3];
        for r in &self.objs {
            for j in 0..3 {
                lo[j] = lo[j].min(r[j]);
                hi[j] = hi[j].max(r[j]);
            }
        }
        self.objs
            .iter()
            .map(|r| {
                let mut out = [0.0; 3];
                for j in 0..3 {
                    let span = hi[j] - lo[j];
                    out[j] = if span > 0.0 { (r[j] - lo[j]) / span } else { 0.0 };
                }
                out
            })
            .collect()
    }

    fn argmin(&self, col: usize) -> Option<SplitPlan> {
        self.plans
            .iter()
            .zip(&self.objs)
            .min_by(|(_, a), (_, b)| a[col].partial_cmp(&b[col]).unwrap())
            .map(|(&p, _)| p)
    }

    fn select(self, strategy: Strategy) -> Solved {
        let plan = match strategy {
            Strategy::Lbo => self.argmin(0),
            Strategy::Ebo => self.argmin(1),
            Strategy::WeightedSum => {
                let w = Strategy::SCALAR_WEIGHTS;
                self.plans
                    .iter()
                    .zip(self.normalised().iter())
                    .min_by(|(_, a), (_, b)| {
                        let sa: f64 = a.iter().zip(&w).map(|(x, wj)| x * wj).sum();
                        let sb: f64 = b.iter().zip(&w).map(|(x, wj)| x * wj).sum();
                        sa.partial_cmp(&sb).unwrap()
                    })
                    .map(|(&p, _)| p)
            }
            Strategy::WeightedMetric => {
                let w = Strategy::SCALAR_WEIGHTS;
                let p_ord = Strategy::METRIC_ORDER;
                let m = |r: &[f64; 3]| -> f64 {
                    r.iter()
                        .zip(&w)
                        .map(|(x, wj)| (wj * x).powf(p_ord))
                        .sum::<f64>()
                        .powf(1.0 / p_ord)
                };
                self.plans
                    .iter()
                    .zip(self.normalised().iter())
                    .min_by(|(_, a), (_, b)| m(a).partial_cmp(&m(b)).unwrap())
                    .map(|(&p, _)| p)
            }
            Strategy::EpsilonConstrained => {
                let primary = Strategy::EPSILON_PRIMARY;
                let eps = Strategy::EPSILON_CEILINGS;
                self.plans
                    .iter()
                    .zip(self.normalised().iter())
                    .filter(|(_, r)| (0..3).all(|j| j == primary || r[j] <= eps[j]))
                    .min_by(|(_, a), (_, b)| a[primary].partial_cmp(&b[primary]).unwrap())
                    .map(|(&p, _)| p)
            }
            other => unreachable!("{other:?} is not a selection-rule strategy"),
        };
        match plan {
            Some(p) => Solved::point(p),
            None => Solved::none(),
        }
    }
}
