//! The planning façade — **the one supported way to ask "where do I
//! split?"**.
//!
//! Three PRs grew four parallel planning paths (the paper path, the
//! cached fleet path, the tiered path, and the baseline free
//! functions), each with its own signature, and every consumer wired
//! its own combination. This module collapses them: a [`PlanRequest`]
//! (model, device/battery state, link, optional edge-tier context,
//! [`Strategy`]) goes in, a [`PlanOutcome`] (universal
//! [`SplitPlan`] `{l1, l2}`, predicted `[latency, energy, memory]`,
//! Pareto-front summary, provenance) comes out, and every backend —
//! NSGA-II+TOPSIS, the exhaustive-front planner, the §VI-C baselines,
//! the §V-A scalarisation methods — plugs in behind
//! [`Planner::plan`]. Two-tier planning is just the degenerate request
//! with no tier context.
//!
//! The [`Planner`] owns the quantisation → key → seed → cache pipeline
//! that `optimizer::cache` introduced: requests are bucketed per the
//! configured bandwidth ratio, the solve seed is derived from the
//! quantised [`PlanKey`], and decisions are memoised in a
//! [`SplitPlanCache`] — so equal states share one solve on any thread,
//! in any order, and turning the cache off changes wall-clock only.
//! `tests/planner_parity.rs` pins the migration invariant: the façade
//! reproduces the pre-redesign entry points' decision streams
//! byte-for-byte.
//!
//! All in-repo consumers (`sim`, `coordinator::fleet`, the live
//! `coordinator`, `figures`, the CLI subcommands, the planner benches)
//! plan exclusively through this module; the old free functions are
//! deprecated shims kept for the parity tests.

mod outcome;
mod request;
mod solve;

use std::collections::HashMap;
use std::sync::Arc;

use crate::edge::SplitPlan;
use crate::metrics::PlannerStats;
use crate::optimizer::{model_cache_id, quantize_bandwidth, Nsga2Params, PlanKey, SplitPlanCache, TierKey};
use crate::util::pool::ThreadPool;
use crate::util::rng::SplitMix64;

pub use outcome::{CacheOutcome, PlanOutcome, Provenance};
pub use request::{PlanRequest, ReplanReason, Strategy, TierContext};

/// How the solve seed is derived for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedMode {
    /// Seed = `PlanKey::derived_seed(base)` — equal quantised states run
    /// the identical solve on any thread, in any order. The fleet/sim
    /// configuration (required for caching to be decision-transparent).
    PerKey,
    /// Seed = the configured base seed, used as-is — what the paper
    /// exhibits ran (`smartsplit(&pm, &params)` with `params.seed`).
    /// Pair with a disabled cache: equal keys would otherwise replay
    /// one seed's decision for every state.
    Fixed,
}

/// Planner configuration: solver budget, seed policy, bandwidth
/// bucketing, and whether decisions are memoised.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// NSGA-II budget for [`Strategy::SmartSplit`] solves (every other
    /// strategy is parameter-free). The `seed` field inside is
    /// overridden per solve according to [`PlannerConfig::seed_mode`].
    pub nsga2: Nsga2Params,
    /// Base seed the per-request solve seeds are derived from.
    pub base_seed: u64,
    /// Geometric bandwidth bucket ratio for plan keys; ≤ 1.0 plans at
    /// exact bandwidth (see [`quantize_bandwidth`]). Quantisation runs
    /// before the solver in cached and uncached paths alike — it shapes
    /// decisions, the cache never does.
    pub bw_bucket_ratio: f64,
    /// Memoise decisions in the planner's [`SplitPlanCache`].
    pub cache: bool,
    pub seed_mode: SeedMode,
}

impl PlannerConfig {
    /// Fleet/sim configuration: key-derived seeds, cache on, exact
    /// bandwidth (callers that bucket pass their ratio explicitly).
    pub fn fleet(nsga2: Nsga2Params, base_seed: u64) -> PlannerConfig {
        PlannerConfig {
            nsga2,
            base_seed,
            bw_bucket_ratio: 1.0,
            cache: true,
            seed_mode: SeedMode::PerKey,
        }
    }

    /// Paper-exhibit configuration: the configured seed used as-is,
    /// no memoisation, exact bandwidth — byte-compatible with the
    /// pre-façade `smartsplit`/`decide` calls the figures ran.
    pub fn paper(nsga2: Nsga2Params) -> PlannerConfig {
        PlannerConfig {
            base_seed: nsga2.seed,
            nsga2,
            bw_bucket_ratio: 1.0,
            cache: false,
            seed_mode: SeedMode::Fixed,
        }
    }

    /// This config with the given bandwidth bucket ratio.
    pub fn with_bucket_ratio(mut self, ratio: f64) -> PlannerConfig {
        self.bw_bucket_ratio = ratio;
        self
    }

    /// This config with the cache toggled.
    pub fn with_cache(mut self, cache: bool) -> PlannerConfig {
        self.cache = cache;
        self
    }
}

/// The planning façade: one [`Planner::plan`] call for every splitting
/// decision in the repo. Cheap to construct; fleet paths hold one for
/// the run so the cache accumulates.
pub struct Planner {
    cfg: PlannerConfig,
    cache: SplitPlanCache,
}

impl Planner {
    pub fn new(cfg: PlannerConfig) -> Planner {
        Planner { cfg, cache: SplitPlanCache::new() }
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.cfg
    }

    /// Split-planner accounting: solves vs cache traffic so far.
    pub fn stats(&self) -> PlannerStats {
        self.cache.stats()
    }

    /// Distinct planner states cached so far.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// The quantised planner state of a request: its cache key and —
    /// for tiered requests — the site parameters with their bucketed
    /// backhaul bandwidth (exactly what the key's [`TierKey`] records).
    fn state(&self, req: &PlanRequest) -> (PlanKey, Option<(crate::edge::EdgeSite, f64)>) {
        let bw_q = quantize_bandwidth(req.bandwidth_mbps, self.cfg.bw_bucket_ratio);
        let mut key = PlanKey::new(
            model_cache_id(&req.model),
            req.profile,
            req.band,
            bw_q,
            req.strategy.kind(),
        );
        let mut site = None;
        if let Some(t) = &req.tier {
            let backhaul_q =
                quantize_bandwidth(t.edge.backhaul.bandwidth_mbps, self.cfg.bw_bucket_ratio);
            key = key.with_tier(TierKey::new(t.site, &t.edge, backhaul_q));
            site = Some((t.edge, backhaul_q));
        }
        (key, site)
    }

    /// The cache key a request quantises to (exposed for tests and
    /// debugging; [`Planner::plan`] computes it internally).
    pub fn key(&self, req: &PlanRequest) -> PlanKey {
        self.state(req).0
    }

    /// The solve seed for a key: key-derived or fixed per the config,
    /// then mixed with the request's independent-run index.
    fn seed_for(&self, key: &PlanKey, run: u64) -> u64 {
        let base = match self.cfg.seed_mode {
            SeedMode::PerKey => key.derived_seed(self.cfg.base_seed),
            SeedMode::Fixed => self.cfg.base_seed,
        };
        if run == 0 {
            base
        } else {
            SplitMix64::new(base ^ run).next_u64()
        }
    }

    /// One split decision. Equal requests give equal decisions whether
    /// served from cache, solved inline, or presolved on a pool worker
    /// — the seed comes from the quantised key.
    pub fn plan(&self, req: &PlanRequest) -> PlanOutcome {
        self.plan_with(req, &mut HashMap::new())
    }

    /// Decision-only fast path: the plan of [`Planner::plan`] without
    /// assembling a [`PlanOutcome`]. A cache hit costs one map lookup —
    /// no [`crate::perfmodel::PerfModel`] build, no objective
    /// evaluation — which is what the 10k-device sweep hot paths (sim
    /// re-optimisation, fleet start) read. Cache accounting is
    /// identical to [`Planner::plan_with`].
    pub fn split(&self, req: &PlanRequest) -> Option<SplitPlan> {
        self.split_with(req, &mut HashMap::new())
    }

    /// As [`Planner::split`], serving cache misses from a
    /// [`Planner::presolve_batch`] result first (the sweep apply
    /// phase).
    pub fn split_with(
        &self,
        req: &PlanRequest,
        presolved: &mut HashMap<PlanKey, Option<SplitPlan>>,
    ) -> Option<SplitPlan> {
        self.cache.counters().record_reason(req.reason.index());
        let (key, site) = self.state(req);
        let bw_q = key.bw_mbps();
        let seed = self.seed_for(&key, req.run);
        let cache_enabled = self.cfg.cache && req.run == 0;
        let pre = if req.run == 0 { presolved.remove(&key) } else { None };
        self.cache.plan(cache_enabled, &key, || {
            pre.unwrap_or_else(|| {
                solve::solve_quantised(
                    req.strategy,
                    req.profile,
                    &req.model,
                    bw_q,
                    req.band,
                    site,
                    &self.cfg.nsga2,
                    seed,
                )
                .plan
            })
        })
    }

    /// As [`Planner::plan`], but a cache miss is served from
    /// `presolved` when a [`Planner::presolve_batch`] fan-out already
    /// solved this key (falling back to an inline solve). Counting runs
    /// through the cache's counted path either way, so a parallel
    /// pass's [`PlannerStats`] are identical to a sequential one.
    ///
    /// Outcome assembly re-evaluates the §III objectives even on cache
    /// hits (cheap table reads, but not free at 10k-device sweep
    /// scale); hot paths that only need the decision should use
    /// [`Planner::split_with`].
    pub fn plan_with(
        &self,
        req: &PlanRequest,
        presolved: &mut HashMap<PlanKey, Option<SplitPlan>>,
    ) -> PlanOutcome {
        self.cache.counters().record_reason(req.reason.index());
        let (key, site) = self.state(req);
        let bw_q = key.bw_mbps();
        let seed = self.seed_for(&key, req.run);
        // Independent-run requests are deliberately distinct solves —
        // memoising them (or serving them from a presolved run-0 batch,
        // whose keys don't encode the run index) would collapse every
        // run onto run 0.
        let cache_enabled = self.cfg.cache && req.run == 0;
        let pre = if req.run == 0 { presolved.remove(&key) } else { None };
        let mut solved = false;
        let mut solved_inline: Option<solve::Solved> = None;
        let plan = self.cache.plan(cache_enabled, &key, || {
            solved = true;
            match pre {
                Some(v) => v,
                None => {
                    let s = solve::solve_quantised(
                        req.strategy,
                        req.profile,
                        &req.model,
                        bw_q,
                        req.band,
                        site,
                        &self.cfg.nsga2,
                        seed,
                    );
                    let plan = s.plan;
                    solved_inline = Some(s);
                    plan
                }
            }
        });
        let cache = if !cache_enabled {
            CacheOutcome::Bypassed
        } else if solved {
            CacheOutcome::Miss
        } else {
            CacheOutcome::Hit
        };
        let objectives =
            plan.map(|p| solve::objectives_of(req.profile, &req.model, bw_q, site, p));
        let (pareto, evaluations) = match solved_inline {
            Some(s) => (s.front, s.evaluations),
            None => (None, 0),
        };
        PlanOutcome {
            plan,
            objectives,
            pareto,
            provenance: Provenance {
                strategy: req.strategy,
                kind: key.kind,
                cache,
                reason: req.reason,
                derived_seed: seed,
                quantized_bw_mbps: bw_q,
                evaluations,
                key,
            },
        }
    }

    /// Fan the distinct, not-yet-cached states behind `requests` out
    /// over `pool` and return their solved plans, keyed for
    /// [`Planner::plan_with`]'s apply phase. Neither the cache contents
    /// nor the counters are touched here, so accounting stays
    /// byte-identical to a sequential pass — parallelism is a pure
    /// wall-clock toggle. No-op when the cache is disabled (every
    /// request then solves inline anyway); independent-run requests are
    /// skipped (they bypass the cache by design).
    pub fn presolve_batch(
        &self,
        pool: &ThreadPool,
        requests: &[PlanRequest],
    ) -> HashMap<PlanKey, Option<SplitPlan>> {
        if !self.cfg.cache {
            return HashMap::new();
        }
        let mut jobs = Vec::with_capacity(requests.len());
        for req in requests {
            if req.run != 0 {
                continue;
            }
            let (key, site) = self.state(req);
            let bw_q = key.bw_mbps();
            let seed = self.seed_for(&key, 0);
            let strategy = req.strategy;
            let profile = req.profile;
            let band = req.band;
            let model = Arc::clone(&req.model);
            let params = self.cfg.nsga2.clone();
            jobs.push((key, move || {
                solve::solve_quantised(strategy, profile, &model, bw_q, band, site, &params, seed)
                    .plan
            }));
        }
        self.cache.presolve_batch(pool, jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::battery::BatteryBand;
    use crate::device::profiles;
    use crate::models::zoo;

    fn req(strategy: Strategy, bw: f64) -> PlanRequest {
        PlanRequest::two_tier(
            Arc::new(zoo::alexnet().analyze(1)),
            profiles::samsung_j6(),
            BatteryBand::Comfort,
            bw,
            strategy,
        )
    }

    #[test]
    fn cache_provenance_hit_miss_bypass() {
        let planner = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let r = req(Strategy::Topsis, 10.0);
        let first = planner.plan(&r);
        assert_eq!(first.provenance.cache, CacheOutcome::Miss);
        let second = planner.plan(&r);
        assert_eq!(second.provenance.cache, CacheOutcome::Hit);
        assert_eq!(first.plan, second.plan);
        // Hits re-evaluate objectives but not fronts.
        assert!(first.pareto.is_some());
        assert!(second.pareto.is_none());
        assert_eq!(first.objectives, second.objectives);

        let uncached =
            Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7).with_cache(false));
        assert_eq!(uncached.plan(&r).provenance.cache, CacheOutcome::Bypassed);
        assert_eq!(uncached.plan(&r).plan, first.plan);
    }

    #[test]
    fn independent_runs_bypass_the_cache_and_vary_rs() {
        let planner = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let base = req(Strategy::Rs, 10.0);
        let canonical = planner.plan(&base);
        assert_eq!(canonical.provenance.cache, CacheOutcome::Miss);
        let mut distinct = std::collections::HashSet::new();
        for run in 1..=20u64 {
            let out = planner.plan(&base.clone().with_run(run));
            assert_eq!(out.provenance.cache, CacheOutcome::Bypassed);
            distinct.insert(out.plan.unwrap().l1);
        }
        assert!(distinct.len() > 1, "independent RS runs never varied");
        // Run 0 stays the canonical cached decision.
        assert_eq!(planner.plan(&base).plan, canonical.plan);
    }

    #[test]
    fn quantisation_collapses_nearby_links_onto_one_state() {
        let planner = Planner::new(
            PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7).with_bucket_ratio(1.25),
        );
        let a = planner.plan(&req(Strategy::Topsis, 10.0));
        let b = planner.plan(&req(Strategy::Topsis, 10.5));
        assert_eq!(a.provenance.key, b.provenance.key);
        assert_eq!(b.provenance.cache, CacheOutcome::Hit);
        assert_eq!(
            a.provenance.quantized_bw_mbps,
            b.provenance.quantized_bw_mbps
        );
    }

    #[test]
    fn strategies_never_share_cache_entries() {
        let planner = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let a = planner.plan(&req(Strategy::Lbo, 10.0));
        let b = planner.plan(&req(Strategy::Ebo, 10.0));
        assert_eq!(a.provenance.cache, CacheOutcome::Miss);
        assert_eq!(b.provenance.cache, CacheOutcome::Miss);
        assert_ne!(a.provenance.key, b.provenance.key);
        assert_eq!(planner.cache_len(), 2);
    }

    #[test]
    fn split_fast_path_matches_plan_and_counts_identically() {
        // The decision-only fast path must be indistinguishable from
        // the full outcome path in decisions, cache contents, and
        // counters — it only skips outcome assembly.
        let full = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let fast = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        for bw in [5.0, 10.0, 30.0] {
            for strategy in [Strategy::Topsis, Strategy::Lbo, Strategy::Rs] {
                let r = req(strategy, bw);
                assert_eq!(full.plan(&r).plan, fast.split(&r));
                assert_eq!(full.plan(&r).plan, fast.split(&r)); // hit path too
            }
        }
        assert_eq!(full.stats(), fast.stats());
        assert_eq!(full.cache_len(), fast.cache_len());
    }

    #[test]
    fn replan_reason_is_provenance_not_planner_state() {
        // A migration re-solve of an already-planned state must be a
        // cache hit (the reason is not in the key), while the per-reason
        // request tallies keep migration asks distinct from spawns.
        let planner = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let spawn = req(Strategy::Topsis, 10.0);
        let migration = spawn.clone().with_reason(ReplanReason::Migration);
        assert_eq!(planner.key(&spawn), planner.key(&migration));

        let first = planner.plan(&spawn);
        assert_eq!(first.provenance.cache, CacheOutcome::Miss);
        assert_eq!(first.provenance.reason, ReplanReason::Spawn);
        let second = planner.plan(&migration);
        assert_eq!(second.provenance.cache, CacheOutcome::Hit);
        assert_eq!(second.provenance.reason, ReplanReason::Migration);
        assert_eq!(first.plan, second.plan);

        let stats = planner.stats();
        assert_eq!(stats.requests_by_reason[ReplanReason::Spawn.index()], 1);
        assert_eq!(stats.requests_by_reason[ReplanReason::Migration.index()], 1);
        assert_eq!(stats.migration_requests(), 1);
        assert_eq!(stats.requests_by_reason.iter().sum::<u64>(), 2);
        assert_eq!(planner.cache_len(), 1, "reason must never fragment the cache");
    }

    #[test]
    fn objectives_match_the_perf_model() {
        let planner = Planner::new(PlannerConfig::fleet(Nsga2Params::for_tiny_genome(), 7));
        let r = req(Strategy::Lbo, 10.0);
        let out = planner.plan(&r);
        let l1 = out.plan.unwrap().l1;
        let pm = crate::optimizer::member_perf_model(r.profile, &r.model, 10.0);
        assert_eq!(out.objectives.unwrap(), pm.objectives(l1));
    }
}
