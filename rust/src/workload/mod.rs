//! Workload generation: synthetic image tensors and request arrival
//! processes for the serving benches and the end-to-end example.
//!
//! The paper's workload is "a set of images" classified one by one (100
//! runs averaged). Image *content* does not affect any measured quantity
//! (DESIGN.md §4), so inputs are deterministic pseudo-random NCHW tensors.

use std::time::Duration;

use crate::util::rng::Xoshiro256;

/// Deterministic synthetic image batch: values ~ N(0, 0.25) like a
/// normalised ImageNet crop.
pub fn synth_images(batch: usize, channels: usize, hw: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..batch * channels * hw * hw)
        .map(|_| (rng.next_normal() * 0.5) as f32)
        .collect()
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from workload start.
    pub arrival: Duration,
    /// Seed for the synthetic image payload.
    pub image_seed: u64,
}

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Closed loop: next request issued immediately (back-to-back).
    ClosedLoop,
    /// Open loop, Poisson arrivals at `rps`.
    Poisson { rps: f64 },
    /// Open loop, uniform spacing at `rps`.
    Uniform { rps: f64 },
    /// Open loop, nonhomogeneous Poisson with sinusoidal daily modulation:
    /// the rate starts at `base_rps` (night trough), peaks at `peak_rps`
    /// half a `period` in, and returns to `base_rps` at the full period —
    /// the diurnal load swing the city-scale simulator and live benches
    /// model.
    Diurnal { base_rps: f64, peak_rps: f64, period: Duration },
}

impl Arrival {
    /// Instantaneous arrival rate at `t_s` seconds from workload start
    /// (requests/second). `ClosedLoop` has no meaningful open-loop rate
    /// and reports `f64::INFINITY`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            Arrival::ClosedLoop => f64::INFINITY,
            Arrival::Poisson { rps } | Arrival::Uniform { rps } => rps,
            Arrival::Diurnal { base_rps, peak_rps, period } => {
                let p = period.as_secs_f64().max(f64::MIN_POSITIVE);
                let phase = std::f64::consts::TAU * t_s / p;
                base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos())
            }
        }
    }
}

/// Draw the next inter-arrival gap for a process observed at `now_s`.
/// `Diurnal` uses Lewis–Shedler thinning against the envelope rate
/// `max(base_rps, peak_rps)`, so generated gaps respect the instantaneous
/// rate at every point of the cycle. Shared by [`generate`] and the
/// event-driven `sim::` workload source.
pub fn next_interarrival(arrival: Arrival, now_s: f64, rng: &mut Xoshiro256) -> f64 {
    match arrival {
        Arrival::ClosedLoop => 0.0,
        Arrival::Poisson { rps } => rng.next_exp(rps),
        Arrival::Uniform { rps } => 1.0 / rps,
        Arrival::Diurnal { base_rps, peak_rps, .. } => {
            let envelope = base_rps.max(peak_rps);
            assert!(envelope > 0.0, "diurnal arrival needs a positive rate");
            let mut t = now_s;
            loop {
                t += rng.next_exp(envelope);
                if rng.next_f64() * envelope < arrival.rate_at(t) {
                    return t - now_s;
                }
            }
        }
    }
}

/// Generate `n` requests under the arrival process.
pub fn generate(n: usize, arrival: Arrival, seed: u64) -> Vec<Request> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += next_interarrival(arrival, t, &mut rng);
            Request {
                id: i as u64,
                arrival: Duration::from_secs_f64(t),
                image_seed: seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_images_deterministic_and_sized() {
        let a = synth_images(2, 3, 8, 42);
        let b = synth_images(2, 3, 8, 42);
        assert_eq!(a.len(), 2 * 3 * 8 * 8);
        assert_eq!(a, b);
        let c = synth_images(2, 3, 8, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn synth_images_distribution_sane() {
        let xs = synth_images(1, 3, 64, 0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!(xs.iter().any(|&x| x > 0.5) && xs.iter().any(|&x| x < -0.5));
    }

    #[test]
    fn closed_loop_all_arrive_at_zero() {
        let reqs = generate(10, Arrival::ClosedLoop, 1);
        assert!(reqs.iter().all(|r| r.arrival == Duration::ZERO));
        assert_eq!(reqs.len(), 10);
        assert_eq!(reqs[9].id, 9);
    }

    #[test]
    fn poisson_mean_rate_close() {
        let reqs = generate(5000, Arrival::Poisson { rps: 100.0 }, 2);
        let total = reqs.last().unwrap().arrival.as_secs_f64();
        let rate = 5000.0 / total;
        assert!((rate - 100.0).abs() / 100.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn uniform_spacing_exact() {
        let reqs = generate(5, Arrival::Uniform { rps: 10.0 }, 3);
        for (i, r) in reqs.iter().enumerate() {
            let expect = 0.1 * (i + 1) as f64;
            assert!((r.arrival.as_secs_f64() - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn diurnal_rate_endpoints() {
        let a = Arrival::Diurnal {
            base_rps: 5.0,
            peak_rps: 50.0,
            period: Duration::from_secs(200),
        };
        assert!((a.rate_at(0.0) - 5.0).abs() < 1e-9);
        assert!((a.rate_at(100.0) - 50.0).abs() < 1e-9);
        assert!((a.rate_at(200.0) - 5.0).abs() < 1e-9);
        // Rate never leaves [base, peak].
        for i in 0..400 {
            let r = a.rate_at(i as f64);
            assert!((5.0 - 1e-9..=50.0 + 1e-9).contains(&r), "t={i} r={r}");
        }
    }

    #[test]
    fn diurnal_interarrivals_track_instantaneous_rate() {
        let arrival = Arrival::Diurnal {
            base_rps: 5.0,
            peak_rps: 50.0,
            period: Duration::from_secs(200),
        };
        // ~one full period at the average rate of 27.5 rps.
        let reqs = generate(5500, arrival, 42);
        let count_in = |lo: f64, hi: f64| {
            reqs.iter()
                .filter(|r| {
                    let t = r.arrival.as_secs_f64();
                    t >= lo && t < hi
                })
                .count() as f64
        };
        let expected_in = |lo: f64, hi: f64| {
            // Numeric ∫ rate dt over the window.
            let steps = 1000;
            let dt = (hi - lo) / steps as f64;
            (0..steps).map(|i| arrival.rate_at(lo + (i as f64 + 0.5) * dt) * dt).sum::<f64>()
        };
        // Trough window (rate ≈ 5–9 rps) vs peak window (rate ≈ 50 rps).
        let trough = count_in(0.0, 20.0);
        let trough_exp = expected_in(0.0, 20.0);
        assert!(
            (trough - trough_exp).abs() / trough_exp < 0.30,
            "trough: saw {trough}, expected {trough_exp}"
        );
        let peak = count_in(90.0, 110.0);
        let peak_exp = expected_in(90.0, 110.0);
        assert!(
            (peak - peak_exp).abs() / peak_exp < 0.15,
            "peak: saw {peak}, expected {peak_exp}"
        );
        // The swing itself: the peak window must be several times busier.
        assert!(peak > 3.0 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn diurnal_generation_is_deterministic() {
        let a = Arrival::Diurnal {
            base_rps: 1.0,
            peak_rps: 10.0,
            period: Duration::from_secs(60),
        };
        let x = generate(200, a, 7);
        let y = generate(200, a, 7);
        assert_eq!(
            x.iter().map(|r| r.arrival).collect::<Vec<_>>(),
            y.iter().map(|r| r.arrival).collect::<Vec<_>>()
        );
    }

    #[test]
    fn image_seeds_unique_per_request() {
        let reqs = generate(100, Arrival::ClosedLoop, 7);
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.image_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 100);
    }
}
