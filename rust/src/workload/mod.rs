//! Workload generation: synthetic image tensors and request arrival
//! processes for the serving benches and the end-to-end example.
//!
//! The paper's workload is "a set of images" classified one by one (100
//! runs averaged). Image *content* does not affect any measured quantity
//! (DESIGN.md §4), so inputs are deterministic pseudo-random NCHW tensors.

use std::time::Duration;

use crate::util::rng::Xoshiro256;

/// Deterministic synthetic image batch: values ~ N(0, 0.25) like a
/// normalised ImageNet crop.
pub fn synth_images(batch: usize, channels: usize, hw: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..batch * channels * hw * hw)
        .map(|_| (rng.next_normal() * 0.5) as f32)
        .collect()
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from workload start.
    pub arrival: Duration,
    /// Seed for the synthetic image payload.
    pub image_seed: u64,
}

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Closed loop: next request issued immediately (back-to-back).
    ClosedLoop,
    /// Open loop, Poisson arrivals at `rps`.
    Poisson { rps: f64 },
    /// Open loop, uniform spacing at `rps`.
    Uniform { rps: f64 },
}

/// Generate `n` requests under the arrival process.
pub fn generate(n: usize, arrival: Arrival, seed: u64) -> Vec<Request> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            let dt = match arrival {
                Arrival::ClosedLoop => 0.0,
                Arrival::Poisson { rps } => rng.next_exp(rps),
                Arrival::Uniform { rps } => 1.0 / rps,
            };
            t += dt;
            Request {
                id: i as u64,
                arrival: Duration::from_secs_f64(t),
                image_seed: seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_images_deterministic_and_sized() {
        let a = synth_images(2, 3, 8, 42);
        let b = synth_images(2, 3, 8, 42);
        assert_eq!(a.len(), 2 * 3 * 8 * 8);
        assert_eq!(a, b);
        let c = synth_images(2, 3, 8, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn synth_images_distribution_sane() {
        let xs = synth_images(1, 3, 64, 0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!(xs.iter().any(|&x| x > 0.5) && xs.iter().any(|&x| x < -0.5));
    }

    #[test]
    fn closed_loop_all_arrive_at_zero() {
        let reqs = generate(10, Arrival::ClosedLoop, 1);
        assert!(reqs.iter().all(|r| r.arrival == Duration::ZERO));
        assert_eq!(reqs.len(), 10);
        assert_eq!(reqs[9].id, 9);
    }

    #[test]
    fn poisson_mean_rate_close() {
        let reqs = generate(5000, Arrival::Poisson { rps: 100.0 }, 2);
        let total = reqs.last().unwrap().arrival.as_secs_f64();
        let rate = 5000.0 / total;
        assert!((rate - 100.0).abs() / 100.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn uniform_spacing_exact() {
        let reqs = generate(5, Arrival::Uniform { rps: 10.0 }, 3);
        for (i, r) in reqs.iter().enumerate() {
            let expect = 0.1 * (i + 1) as f64;
            assert!((r.arrival.as_secs_f64() - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn image_seeds_unique_per_request() {
        let reqs = generate(100, Arrival::ClosedLoop, 7);
        let mut seeds: Vec<u64> = reqs.iter().map(|r| r.image_seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 100);
    }
}
