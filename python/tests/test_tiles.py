"""Tile-picker invariants (§Perf L1): both profiles must produce legal,
budget-respecting schedules for every layer shape in the zoo."""

import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    CPU_BUDGET_WORDS,
    VMEM_BUDGET_WORDS,
    get_tile_profile,
    pick_tiles,
    set_tile_profile,
)
from compile import specs, zoo


@settings(max_examples=60, deadline=None)
@given(m=st.integers(1, 5000), k=st.integers(1, 30000), n=st.integers(1, 60000))
def test_tpu_profile_respects_vmem_budget(m, k, n):
    tm, tn, tk = pick_tiles(m, k, n, "tpu")
    words = tm * tk + tk * tn + tm * tn
    # Budget may be exceeded only when the MINIMUM legal tile (K streamed at
    # the floor TK) already exceeds it — never by the picker's choice of a
    # larger TK.
    floor_words = tm * 512 + 512 * tn + tm * tn
    assert words <= max(VMEM_BUDGET_WORDS, floor_words) + 8 * (tm + tn)
    for t in (tm, tn, tk):
        assert t % 8 == 0 or t == min(t, 8)
    assert tm >= min(m, 8) and tk >= min(k, 8)


@settings(max_examples=60, deadline=None)
@given(m=st.integers(1, 512), k=st.integers(1, 30000), n=st.integers(1, 600000))
def test_cpu_profile_minimises_grid_steps(m, k, n):
    tm, tn, tk = pick_tiles(m, k, n, "cpu")
    # Full M and K in one block (the interpret-mode cost model).
    assert tm >= m and tk >= k
    words = tm * tk + tk * tn + tm * tn
    small = tm * tk + (tk + tm) * 128
    assert words <= max(CPU_BUDGET_WORDS + 8 * (tk + tm), small)


def test_profile_toggle_roundtrip():
    old = get_tile_profile()
    try:
        set_tile_profile("tpu")
        assert get_tile_profile() == "tpu"
        assert pick_tiles(1, 9216, 4096) == pick_tiles(1, 9216, 4096, "tpu")
        set_tile_profile("cpu")
        assert pick_tiles(1, 9216, 4096)[2] >= 9216
    finally:
        set_tile_profile(old)
    with pytest.raises(AssertionError):
        set_tile_profile("gpu")


def test_zoo_matmul_shapes_few_steps_under_cpu_profile():
    """Every linear layer in the zoo runs in at most TWO grid steps at
    batch<=8 under the cpu profile (the §Perf fc1 fix, 32.4 s → ms);
    AlexNet's fc layers — the measured pathology — in exactly one.
    (VGG's 25088x4096 fc1 needs two N-tiles to stay under the 256 MiB
    working-set budget.)"""
    for name, f in zoo.ZOO.items():
        model = f()
        for layer in model.layers:
            if isinstance(layer, specs.Linear):
                for b in (1, 8):
                    tm, tn, tk = pick_tiles(b, layer.in_features, layer.out_features, "cpu")
                    steps = (
                        -(-b // tm) * -(-layer.out_features // tn) * -(-layer.in_features // tk)
                    )
                    assert steps <= 2, (name, layer, steps)
                    if name == "alexnet":
                        assert steps == 1, (layer, steps)
