"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Hypothesis sweeps shapes / strides / paddings / activations; every property
asserts allclose against ``kernels.ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
from compile.kernels import (
    conv2d_pallas,
    depthwise_conv_pallas,
    matmul_pallas,
    maxpool2d_pallas,
    ref,
    vmem_bytes,
)

SETTINGS = dict(max_examples=25, deadline=None)


def rnd(rng, shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 70),
    bias=st.booleans(),
    act=st.sampled_from([None, "relu", "relu6"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, bias, act, seed):
    rng = np.random.RandomState(seed)
    x, w = rnd(rng, (m, k)), rnd(rng, (k, n))
    b = rnd(rng, (n,)) if bias else None
    got = matmul_pallas(x, w, b, act)
    want = ref.matmul_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40), k=st.integers(1, 600), n=st.integers(1, 40),
    tm=st.sampled_from([8, 16, 128]), tn=st.sampled_from([8, 16, 128]),
    tk=st.sampled_from([8, 64, 512]),
)
def test_matmul_tile_shapes_dont_change_result(m, k, n, tm, tn, tk):
    """Tiling is a pure schedule: any (tm, tn, tk) gives the same numbers."""
    rng = np.random.RandomState(m * 1000 + k * 10 + n)
    x, w = rnd(rng, (m, k)), rnd(rng, (k, n))
    got = matmul_pallas(x, w, tm=tm, tn=tn, tk=tk)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_large_contraction():
    """K ~ fc1-of-VGG scale accumulation stays accurate."""
    rng = np.random.RandomState(0)
    x, w = rnd(rng, (4, 2048)), rnd(rng, (2048, 64))
    np.testing.assert_allclose(
        matmul_pallas(x, w), ref.matmul_ref(x, w), rtol=1e-3, atol=1e-3
    )


def test_vmem_budget():
    """Default tiles fit well inside a 16 MiB VMEM with 2x double-buffering."""
    assert 2 * vmem_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# conv2d (im2col + matmul)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 8),
    oc=st.integers(1, 12),
    hw=st.integers(5, 20),
    kernel=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 3),
    padding=st.integers(0, 2),
    bias=st.booleans(),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, c, oc, hw, kernel, stride, padding, bias, act, seed):
    if hw + 2 * padding < kernel:
        return
    rng = np.random.RandomState(seed)
    x = rnd(rng, (n, c, hw, hw))
    w = rnd(rng, (oc, c, kernel, kernel))
    b = rnd(rng, (oc,)) if bias else None
    got = conv2d_pallas(x, w, b, stride, padding, act)
    want = ref.conv2d_ref(x, w, b, stride, padding, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv2d_folded_bn(seed):
    rng = np.random.RandomState(seed)
    x, w = rnd(rng, (2, 4, 10, 10)), rnd(rng, (6, 4, 3, 3))
    s = rng.uniform(0.5, 1.5, (6,)).astype(np.float32)
    sh = rnd(rng, (6,)) * 0.1
    got = conv2d_pallas(x, w, None, 1, 1, "relu6", s, sh)
    want = ref.conv2d_ref(x, w, None, 1, 1, act="relu6", bn_scale=s, bn_shift=sh)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_alexnet_first_layer_shape():
    rng = np.random.RandomState(0)
    x, w, b = rnd(rng, (1, 3, 224, 224)), rnd(rng, (64, 3, 11, 11)), rnd(rng, (64,))
    got = conv2d_pallas(x, w, b, 4, 2)
    assert got.shape == (1, 64, 55, 55)
    np.testing.assert_allclose(
        got, ref.conv2d_ref(x, w, b, 4, 2), rtol=1e-3, atol=1e-3
    )


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    c=st.integers(1, 48),
    hw=st.integers(4, 20),
    stride=st.sampled_from([1, 2]),
    act=st.sampled_from([None, "relu6"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_ref(c, hw, stride, act, seed):
    rng = np.random.RandomState(seed)
    x, w = rnd(rng, (1, c, hw, hw)), rnd(rng, (c, 1, 3, 3))
    got = depthwise_conv_pallas(x, w, stride, 1, act)
    want = ref.depthwise_conv_ref(x, w, stride, 1, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_depthwise_folded_bn(seed):
    rng = np.random.RandomState(seed)
    x, w = rnd(rng, (1, 16, 9, 9)), rnd(rng, (16, 1, 3, 3))
    s = rng.uniform(0.5, 1.5, (16,)).astype(np.float32)
    sh = rnd(rng, (16,)) * 0.1
    got = depthwise_conv_pallas(x, w, 1, 1, "relu6", s, sh)
    want = ref.depthwise_conv_ref(x, w, 1, 1, act="relu6", bn_scale=s, bn_shift=sh)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 70),
    hw=st.integers(4, 30),
    kernel=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(n, c, hw, kernel, stride, seed):
    if hw < kernel:
        return
    rng = np.random.RandomState(seed)
    x = rnd(rng, (n, c, hw, hw))
    got = maxpool2d_pallas(x, kernel, stride)
    want = ref.maxpool2d_ref(x, kernel, stride)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_maxpool_negative_inputs_not_clobbered_by_padding():
    """Channel padding must not leak zeros into real channels' max."""
    x = -np.ones((1, 5, 6, 6), np.float32)
    got = maxpool2d_pallas(x, 2, 2, tc=4)  # forces channel padding
    np.testing.assert_allclose(got, -np.ones((1, 5, 3, 3), np.float32))


# ---------------------------------------------------------------------------
# adaptive avgpool oracle sanity (used directly by L2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hw,out", [(6, 6), (7, 7), (13, 6), (55, 6), (7, 1)])
def test_adaptive_avgpool_shapes(hw, out):
    rng = np.random.RandomState(0)
    x = rnd(rng, (1, 4, hw, hw))
    y = ref.adaptive_avgpool2d_ref(x, out)
    assert y.shape == (1, 4, out, out)
    if hw == out:
        np.testing.assert_allclose(y, x)


def test_adaptive_avgpool_identity_mean():
    x = np.ones((2, 3, 13, 13), np.float32) * 5.0
    np.testing.assert_allclose(ref.adaptive_avgpool2d_ref(x, 6), np.full((2, 3, 6, 6), 5.0))
