"""Shape / parameter / memory algebra: inference in ``specs`` must match
what jax actually computes, and the published parameter counts."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as mdl
from compile import specs, zoo


@pytest.mark.parametrize("name,layers", sorted(zoo.PAPER_LAYERS.items()))
def test_paper_layer_counts(name, layers):
    assert zoo.ZOO[name]().num_layers == layers


# Published torchvision parameter counts.
@pytest.mark.parametrize(
    "name,params",
    [
        ("alexnet", 61_100_840),
        ("vgg11", 132_863_336),
        ("vgg13", 133_047_848),
        ("vgg16", 138_357_544),
    ],
)
def test_published_param_counts(name, params):
    assert specs.total_params(zoo.ZOO[name]()) == params


def test_mobilenet_param_count_close_to_published():
    # Folded BN counts scale+shift (2/ch) where torch counts
    # weight+bias+running stats; the trainable count is ~3.50M.
    p = specs.total_params(zoo.mobilenet_v2())
    assert abs(p - 3_504_872) / 3_504_872 < 0.01


@pytest.mark.parametrize("name", sorted(zoo.ZOO))
def test_shape_inference_matches_jax(name):
    """analyze() shapes == actual jax forward shapes, layer by layer."""
    model = zoo.ZOO[name]()
    small = 224  # classifier in_features pin the input size
    infos = specs.analyze(model, batch=1)
    params = mdl.init_model_params(model, 0)
    x = np.zeros((1, 3, small, small), np.float32)
    for layer, p, info in zip(model.layers, params, infos):
        ws = [a for _, a in mdl.flat_weights(layer, p)]
        x = np.asarray(mdl.layer_fn(layer, "ref")(x, *ws))
        assert x.shape == info.out_shape, f"{name} layer {info.index} {info.kind}"


def test_client_memory_monotone_nondecreasing():
    infos = specs.analyze(zoo.alexnet())
    mems = [specs.client_memory_bytes(infos, l) for l in range(1, 22)]
    assert all(b >= a for a, b in zip(mems, mems[1:]))
    assert mems[0] > 0


def test_client_plus_server_memory_is_total():
    infos = specs.analyze(zoo.vgg11())
    total = specs.client_memory_bytes(infos, len(infos))
    for l1 in range(1, len(infos) + 1):
        assert (
            specs.client_memory_bytes(infos, l1) + specs.server_memory_bytes(infos, l1)
            == total
        )


def test_intermediate_bytes_alexnet():
    infos = specs.analyze(zoo.alexnet())
    # layer 1 output: (1, 64, 55, 55) f32
    assert specs.intermediate_bytes(infos, 1) == 64 * 55 * 55 * 4
    # final output: 1000 logits
    assert specs.intermediate_bytes(infos, 21) == 1000 * 4


def test_relu_dropout_zero_params():
    for layer in (specs.ReLU(), specs.ReLU6(), specs.Dropout(), specs.MaxPool2d(2, 2)):
        assert specs.param_count(layer) == 0


def test_conv_out_hw_formula():
    assert specs.conv_out_hw(224, 11, 4, 2) == 55  # AlexNet conv1
    assert specs.conv_out_hw(224, 3, 1, 1) == 224  # VGG conv
    assert specs.conv_out_hw(224, 3, 2, 1) == 112  # MobileNet stem


@settings(max_examples=50, deadline=None)
@given(
    h=st.integers(1, 300),
    k=st.integers(1, 11),
    s=st.integers(1, 4),
    p=st.integers(0, 5),
)
def test_conv_out_hw_matches_definition(h, k, s, p):
    if h + 2 * p < k:
        return
    expected = len(range(0, h + 2 * p - k + 1, s))
    assert specs.conv_out_hw(h, k, s, p) == expected


@settings(max_examples=30, deadline=None)
@given(
    inc=st.integers(1, 32),
    outc=st.integers(1, 32),
    k=st.sampled_from([1, 3, 5]),
    bias=st.booleans(),
)
def test_conv_param_count_matches_array_sizes(inc, outc, k, bias):
    layer = specs.Conv2d(inc, outc, k, bias=bias)
    p = mdl.init_layer_params(layer, np.random.RandomState(0))
    assert specs.param_count(layer) == sum(a.size for a in p.values())


@settings(max_examples=30, deadline=None)
@given(
    inc=st.sampled_from([16, 24, 32]),
    outc=st.sampled_from([16, 24, 32]),
    stride=st.sampled_from([1, 2]),
    t=st.sampled_from([1, 6]),
)
def test_inverted_residual_param_count_matches_arrays(inc, outc, stride, t):
    layer = specs.InvertedResidual(inc, outc, stride, t)
    p = mdl.init_layer_params(layer, np.random.RandomState(0))
    assert specs.param_count(layer) == sum(a.size for a in p.values())


def test_flops_alexnet_total_magnitude():
    """AlexNet forward ~0.71 GMACs => ~1.4 GFLOPs at batch 1."""
    infos = specs.analyze(zoo.alexnet())
    total = sum(i.flops for i in infos)
    assert 1.3e9 < total < 1.7e9


def test_flops_vgg16_total_magnitude():
    """VGG16 forward ~15.5 GMACs => ~31 GFLOPs at batch 1."""
    infos = specs.analyze(zoo.vgg16())
    total = sum(i.flops for i in infos)
    assert 29e9 < total < 33e9


def test_flops_scale_linearly_with_batch():
    i1 = specs.analyze(zoo.alexnet(), batch=1)
    i8 = specs.analyze(zoo.alexnet(), batch=8)
    conv_idx = [k for k, i in enumerate(i1) if i.kind in ("conv2d", "linear")]
    for k in conv_idx:
        assert i8[k].flops == 8 * i1[k].flops
