"""L2 model correctness: per-layer pallas fns vs the ref oracle, weight
ordering contract, and end-to-end forward equivalence."""

import numpy as np
import pytest

from compile import model as mdl
from compile import specs, zoo


def test_weight_order_is_wire_contract():
    """flat_weights order must match WEIGHT_ORDER (the manifest contract)."""
    conv = specs.Conv2d(3, 8, 3, bias=True, folded_bn=True)
    p = mdl.init_layer_params(conv, np.random.RandomState(0))
    names = [n for n, _ in mdl.flat_weights(conv, p)]
    assert names == ["w", "b", "bn_scale", "bn_shift"]

    ir = specs.InvertedResidual(16, 24, 2, 6)
    p = mdl.init_layer_params(ir, np.random.RandomState(0))
    names = [n for n, _ in mdl.flat_weights(ir, p)]
    assert names == mdl.WEIGHT_ORDER["inverted_residual"]

    ir1 = specs.InvertedResidual(32, 16, 1, 1)  # expand_ratio=1: no exp_*
    p = mdl.init_layer_params(ir1, np.random.RandomState(0))
    names = [n for n, _ in mdl.flat_weights(ir1, p)]
    assert names == ["dw_w", "dw_bn_scale", "dw_bn_shift",
                     "proj_w", "proj_bn_scale", "proj_bn_shift"]


def test_init_is_deterministic():
    a = mdl.init_model_params(zoo.alexnet(), seed=7)
    b = mdl.init_model_params(zoo.alexnet(), seed=7)
    for pa, pb in zip(a, b):
        for k in pa:
            np.testing.assert_array_equal(pa[k], pb[k])


def test_dropout_is_identity():
    fn = mdl.layer_fn(specs.Dropout(0.5))
    x = np.random.RandomState(0).standard_normal((2, 10)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(fn(x)), x)


def test_relu6_clips():
    fn = mdl.layer_fn(specs.ReLU6())
    x = np.array([[-1.0, 0.5, 7.0]], np.float32)
    np.testing.assert_allclose(np.asarray(fn(x)), [[0.0, 0.5, 6.0]])


def test_linear_implicit_flatten_matches_explicit():
    layer = specs.Linear(4 * 3 * 3, 5)
    p = mdl.init_layer_params(layer, np.random.RandomState(0))
    x4 = np.random.RandomState(1).standard_normal((2, 4, 3, 3)).astype(np.float32)
    ws = [a for _, a in mdl.flat_weights(layer, p)]
    y4 = np.asarray(mdl.layer_fn(layer, "ref")(x4, *ws))
    y2 = np.asarray(mdl.layer_fn(layer, "ref")(x4.reshape(2, -1), *ws))
    np.testing.assert_allclose(y4, y2, rtol=1e-6)


def test_linear_global_pool_is_mean():
    layer = specs.Linear(4, 5, global_pool=True)
    p = mdl.init_layer_params(layer, np.random.RandomState(0))
    ws = [a for _, a in mdl.flat_weights(layer, p)]
    x = np.random.RandomState(1).standard_normal((2, 4, 3, 3)).astype(np.float32)
    y = np.asarray(mdl.layer_fn(layer, "ref")(x, *ws))
    y_manual = np.asarray(mdl.layer_fn(layer, "ref")(x.mean(axis=(2, 3)), *ws))
    np.testing.assert_allclose(y, y_manual, rtol=1e-6)


def test_inverted_residual_uses_residual_only_when_shapes_allow():
    rng = np.random.RandomState(0)
    x = rng.standard_normal((1, 16, 8, 8)).astype(np.float32)

    res = specs.InvertedResidual(16, 16, 1, 6)
    assert res.use_residual
    p = mdl.init_layer_params(res, rng)
    ws = [a for _, a in mdl.flat_weights(res, p)]
    y_with = np.asarray(mdl.layer_fn(res, "ref")(x, *ws))

    nores = specs.InvertedResidual(16, 24, 1, 6)
    assert not nores.use_residual
    strided = specs.InvertedResidual(16, 16, 2, 6)
    assert not strided.use_residual

    # Zero all weights: residual block must return x itself, non-residual 0.
    ws0 = [np.zeros_like(a) for a in ws]
    np.testing.assert_allclose(np.asarray(mdl.layer_fn(res, "ref")(x, *ws0)), x)


@pytest.mark.parametrize("name", ["alexnet", "mobilenet_v2"])
def test_forward_pallas_matches_ref_full_model(name):
    """Full-depth pallas == ref on a reduced input (224 is too slow for
    interpret-mode CI; the AOT artifacts use 224 and are validated by the
    rust integration tests against this same oracle)."""
    model = zoo.ZOO[name]()
    params = mdl.init_model_params(model, 0)
    x = np.random.RandomState(2).standard_normal((1, 3, 224, 224)).astype(np.float32) * 0.1
    if name == "alexnet":
        # run only the conv trunk at 224 (classifier checked separately below)
        upto = 14
    else:
        upto = model.num_layers
    yp = np.asarray(mdl.model_forward(model, params, x, "pallas", upto=upto))
    yr = np.asarray(mdl.model_forward(model, params, x, "ref", upto=upto))
    np.testing.assert_allclose(yp, yr, rtol=5e-3, atol=5e-3)


def test_alexnet_classifier_pallas_matches_ref():
    model = zoo.alexnet()
    params = mdl.init_model_params(model, 0)
    rng = np.random.RandomState(3)
    x = rng.standard_normal((1, 256, 6, 6)).astype(np.float32)
    for i in range(14, 21):  # dropout/linear/relu tail
        layer, p = model.layers[i], params[i]
        ws = [a for _, a in mdl.flat_weights(layer, p)]
        xp = np.asarray(mdl.layer_fn(layer, "pallas")(x, *ws))
        xr = np.asarray(mdl.layer_fn(layer, "ref")(x, *ws))
        np.testing.assert_allclose(xp, xr, rtol=1e-3, atol=1e-3)
        x = xr
