"""AOT pipeline: HLO text format, manifest contract, CLI arg parsing."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as mdl, specs, zoo


def test_parse_model_arg():
    assert aot.parse_model_arg("vgg11") == ("vgg11", [1])
    assert aot.parse_model_arg("alexnet:1,8") == ("alexnet", [1, 8])


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_lower_layer_entry_layout_has_weights_as_params():
    """Weights must be HLO parameters (the manifest/runtime contract),
    never giant text constants."""
    layer = specs.Conv2d(3, 4, 3, padding=1)
    p = mdl.init_layer_params(layer, np.random.RandomState(0))
    text = aot.lower_layer(layer, (1, 3, 8, 8), p)
    head = text.splitlines()[0]
    # activation + w + b = 3 params in the entry layout
    assert "f32[1,3,8,8]" in head and "f32[4,3,3,3]" in head and "f32[4]" in head
    assert "->f32[1,4,8,8]" in head  # bare array return (buffer chaining)


def test_lower_layer_bare_return_for_identity():
    layer = specs.Dropout()
    text = aot.lower_layer(layer, (1, 10), {})
    assert "->f32[1,10]" in text.splitlines()[0]


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    """A 4-layer toy model through the full artifact pipeline."""
    out = tmp_path_factory.mktemp("artifacts")
    model = specs.ModelSpec(
        "tiny",
        (
            specs.Conv2d(3, 4, 3, stride=2, padding=1),
            specs.ReLU(),
            specs.MaxPool2d(2, 2),
            specs.Linear(4 * 4 * 4, 7),
        ),
        input_hw=16,
        top1_accuracy=0.5,
    )
    zoo.PAPER_LAYERS["tiny"] = 4
    manifest = aot.build_model_artifacts(model, str(out), batches=(1, 2),
                                         verbose=False)
    return out, model, manifest


def test_manifest_contents(tiny_artifacts):
    out, model, manifest = tiny_artifacts
    ondisk = json.load(open(out / "tiny" / "manifest.json"))
    assert ondisk == manifest
    assert manifest["num_layers"] == 4
    assert manifest["batches"] == [1, 2]
    ls = manifest["layers"]
    assert [l["kind"] for l in ls] == ["conv2d", "relu", "maxpool2d", "linear"]
    assert ls[0]["out_shape"] == [1, 4, 8, 8]
    assert ls[2]["out_shape"] == [1, 4, 4, 4]
    assert ls[3]["out_shape"] == [1, 7]
    # act_bytes is the I|l1 contract
    assert ls[0]["act_bytes"] == 4 * 8 * 8 * 4
    # params: conv 4*3*3*3+4, linear 64*7+7
    assert ls[0]["params"] == 112 and ls[3]["params"] == 455


def test_artifact_files_exist_and_weights_roundtrip(tiny_artifacts):
    out, model, manifest = tiny_artifacts
    mdir = out / "tiny"
    params = mdl.init_model_params(model, manifest["seed"])
    for l in manifest["layers"]:
        for b in ("1", "2"):
            path = mdir / l["hlo"][b]
            assert path.exists()
            assert path.read_text().startswith("HloModule")
        for wmeta, (name, arr) in zip(l["weights"],
                                      mdl.flat_weights(model.layers[l["index"] - 1],
                                                       params[l["index"] - 1])):
            assert wmeta["name"] == name
            data = np.fromfile(mdir / wmeta["file"], dtype="<f4")
            np.testing.assert_array_equal(data.reshape(wmeta["shape"]), arr)


def test_batch_variant_shapes(tiny_artifacts):
    out, _, manifest = tiny_artifacts
    text = (out / "tiny" / manifest["layers"][0]["hlo"]["2"]).read_text()
    assert "f32[2,3,16,16]" in text.splitlines()[0]


def test_real_manifests_on_disk_if_built():
    """When `make artifacts` has run, validate the real manifests'
    cross-layer consistency (shape chaining + paper layer counts)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(root):
        pytest.skip("artifacts not built")
    for name, expect in zoo.PAPER_LAYERS.items():
        if name == "tiny":
            continue
        mpath = os.path.join(root, name, "manifest.json")
        if not os.path.exists(mpath):
            continue
        m = json.load(open(mpath))
        assert m["num_layers"] == expect == m["paper_layers"]
        ls = m["layers"]
        for a, b in zip(ls, ls[1:]):
            assert a["out_shape"] == b["in_shape"]
        assert ls[-1]["out_shape"] == [1, m["num_classes"]]
