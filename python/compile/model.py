"""L2: per-layer jax forward functions for the CNN zoo, calling the L1
Pallas kernels.

Every paper "layer" becomes an independent jax function
``fn(activation, *weights) -> activation`` so that ``aot.py`` can lower each
one to its own HLO module. Weights are *runtime parameters* (not HLO
constants): VGG16's fc1 alone is 102.7M f32 values, which as HLO text
constants would be gigabytes; instead weights live in little-endian ``.bin``
files the rust runtime feeds as PJRT literals (uploaded once, reused across
requests).

``impl`` selects the kernel implementation: ``"pallas"`` (L1 kernels, the
real artifact path) or ``"ref"`` (pure jnp oracle) — the ablation bench
compares the two.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import specs
from .kernels import (
    conv2d_pallas,
    depthwise_conv_pallas,
    matmul_pallas,
    maxpool2d_pallas,
    ref,
)

Params = Dict[str, np.ndarray]


# ---------------------------------------------------------------------------
# Weight initialisation (He-normal convs, Xavier-uniform linears).
# Random weights are a documented substitution (DESIGN.md §4): no network
# access for torchvision checkpoints, and none of the measured quantities
# (latency / energy / memory) depend on weight *values*.
# ---------------------------------------------------------------------------


def init_layer_params(layer, rng: np.random.RandomState) -> Params:
    if isinstance(layer, specs.Conv2d):
        fan_in = (layer.in_ch // layer.groups) * layer.kernel * layer.kernel
        p: Params = {
            "w": (rng.standard_normal(
                (layer.out_ch, layer.in_ch // layer.groups, layer.kernel, layer.kernel)
            ) * np.sqrt(2.0 / fan_in)).astype(np.float32)
        }
        if layer.bias:
            p["b"] = np.zeros((layer.out_ch,), np.float32)
        if layer.folded_bn:
            p["bn_scale"] = rng.uniform(0.5, 1.5, (layer.out_ch,)).astype(np.float32)
            p["bn_shift"] = (rng.standard_normal((layer.out_ch,)) * 0.1).astype(np.float32)
        return p
    if isinstance(layer, specs.Linear):
        bound = np.sqrt(1.0 / layer.in_features)
        p = {"w": rng.uniform(-bound, bound,
                              (layer.in_features, layer.out_features)).astype(np.float32)}
        if layer.bias:
            p["b"] = rng.uniform(-bound, bound, (layer.out_features,)).astype(np.float32)
        return p
    if isinstance(layer, specs.InvertedResidual):
        hid = layer.hidden_ch
        p = {}
        if layer.expand_ratio != 1:
            p["exp_w"] = (rng.standard_normal((hid, layer.in_ch, 1, 1))
                          * np.sqrt(2.0 / layer.in_ch)).astype(np.float32)
            p["exp_bn_scale"] = rng.uniform(0.5, 1.5, (hid,)).astype(np.float32)
            p["exp_bn_shift"] = (rng.standard_normal((hid,)) * 0.1).astype(np.float32)
        p["dw_w"] = (rng.standard_normal((hid, 1, 3, 3)) * np.sqrt(2.0 / 9)).astype(np.float32)
        p["dw_bn_scale"] = rng.uniform(0.5, 1.5, (hid,)).astype(np.float32)
        p["dw_bn_shift"] = (rng.standard_normal((hid,)) * 0.1).astype(np.float32)
        p["proj_w"] = (rng.standard_normal((layer.out_ch, hid, 1, 1))
                       * np.sqrt(2.0 / hid)).astype(np.float32)
        p["proj_bn_scale"] = rng.uniform(0.5, 1.5, (layer.out_ch,)).astype(np.float32)
        p["proj_bn_shift"] = (rng.standard_normal((layer.out_ch,)) * 0.1).astype(np.float32)
        return p
    return {}


# Deterministic flat ordering of each layer's weights: this IS the wire
# contract with the rust runtime (manifest lists names in this order).
WEIGHT_ORDER = {
    "conv2d": ["w", "b", "bn_scale", "bn_shift"],
    "linear": ["w", "b"],
    "inverted_residual": [
        "exp_w", "exp_bn_scale", "exp_bn_shift",
        "dw_w", "dw_bn_scale", "dw_bn_shift",
        "proj_w", "proj_bn_scale", "proj_bn_shift",
    ],
}


def flat_weights(layer, params: Params) -> List[Tuple[str, np.ndarray]]:
    order = WEIGHT_ORDER.get(layer.kind, [])
    return [(k, params[k]) for k in order if k in params]


def init_model_params(model: specs.ModelSpec, seed: int = 0) -> List[Params]:
    rng = np.random.RandomState(seed)
    return [init_layer_params(l, rng) for l in model.layers]


# ---------------------------------------------------------------------------
# Per-layer forward functions
# ---------------------------------------------------------------------------


def _inverted_residual_fn(layer: specs.InvertedResidual, impl: str):
    conv = conv2d_pallas if impl == "pallas" else (
        lambda x, w, b, s, p, act, bn_scale, bn_shift: ref.conv2d_ref(
            x, w, b, s, p, act=act, bn_scale=bn_scale, bn_shift=bn_shift))
    dw = depthwise_conv_pallas if impl == "pallas" else (
        lambda x, w, s, p, act, bn_scale, bn_shift: ref.depthwise_conv_ref(
            x, w, s, p, act=act, bn_scale=bn_scale, bn_shift=bn_shift))

    def fn(x, *ws):
        i = 0
        h = x
        if layer.expand_ratio != 1:
            ew, es, eb = ws[i], ws[i + 1], ws[i + 2]
            i += 3
            h = conv(h, ew, None, 1, 0, "relu6", es, eb)
        dww, dws, dwb = ws[i], ws[i + 1], ws[i + 2]
        i += 3
        h = dw(h, dww, layer.stride, 1, "relu6", dws, dwb)
        pw, ps, pb = ws[i], ws[i + 1], ws[i + 2]
        h = conv(h, pw, None, 1, 0, None, ps, pb)
        if layer.use_residual:
            h = h + x
        return h

    return fn


def layer_fn(layer, impl: str = "pallas") -> Callable:
    """Return ``fn(activation, *weights) -> activation`` for one layer."""
    pallas = impl == "pallas"
    if isinstance(layer, specs.Conv2d):
        if layer.groups != 1:
            raise NotImplementedError("grouped conv only via InvertedResidual")
        return _make_conv(layer, pallas)
    if isinstance(layer, specs.Linear):
        return _make_linear(layer, pallas)
    if isinstance(layer, specs.InvertedResidual):
        return _inverted_residual_fn(layer, impl)
    if isinstance(layer, specs.ReLU):
        return lambda x: jnp.maximum(x, 0.0)
    if isinstance(layer, specs.ReLU6):
        return lambda x: jnp.clip(x, 0.0, 6.0)
    if isinstance(layer, specs.Dropout):
        return lambda x: x  # inference identity
    if isinstance(layer, specs.MaxPool2d):
        if pallas:
            return lambda x: maxpool2d_pallas(x, layer.kernel, layer.stride)
        return lambda x: ref.maxpool2d_ref(x, layer.kernel, layer.stride)
    if isinstance(layer, specs.AdaptiveAvgPool2d):
        return lambda x: ref.adaptive_avgpool2d_ref(x, layer.out_hw)
    if isinstance(layer, specs.Flatten):
        return lambda x: x.reshape(x.shape[0], -1)
    raise TypeError(f"unknown layer {layer!r}")


def _make_conv(layer: specs.Conv2d, pallas: bool) -> Callable:
    has_bias, has_bn = layer.bias, layer.folded_bn

    def fn(x, *ws):
        w = ws[0]
        i = 1
        b = ws[i] if has_bias else None
        i += int(has_bias)
        bn_s = ws[i] if has_bn else None
        bn_b = ws[i + 1] if has_bn else None
        if pallas:
            return conv2d_pallas(x, w, b, layer.stride, layer.padding,
                                 None, bn_s, bn_b)
        return ref.conv2d_ref(x, w, b, layer.stride, layer.padding,
                              act=None, bn_scale=bn_s, bn_shift=bn_b)

    return fn


def _make_linear(layer: specs.Linear, pallas: bool) -> Callable:
    has_bias, gp = layer.bias, layer.global_pool

    def fn(x, *ws):
        w = ws[0]
        b = ws[1] if has_bias else None
        if x.ndim == 4:
            x = jnp.mean(x, axis=(2, 3)) if gp else x.reshape(x.shape[0], -1)
        if pallas:
            return matmul_pallas(x, w, b, None)
        return ref.matmul_ref(x, w, b, None)

    return fn


def model_forward(
    model: specs.ModelSpec,
    params: Sequence[Params],
    x: jax.Array,
    impl: str = "pallas",
    upto: Optional[int] = None,
) -> jax.Array:
    """Run layers 1..upto (all if None). Used by tests and the oracle."""
    n = len(model.layers) if upto is None else upto
    for layer, p in zip(model.layers[:n], params[:n]):
        ws = [jnp.asarray(a) for _, a in flat_weights(layer, p)]
        x = layer_fn(layer, impl)(x, *ws)
    return x
