"""Declarative CNN layer specifications with shape / parameter / FLOPs /
memory inference.

A "layer" here mirrors one torchvision ``nn.Module`` in the flattened
``features → avgpool → classifier`` ordering, because that is how the paper
counts layers (AlexNet 21, VGG11 29, VGG13 33, VGG16 39, MobileNetV2 21).
The rust side (``rust/src/models``) implements the same algebra; the
manifest emitted by ``aot.py`` is the cross-check contract between the two.

Memory accounting follows the paper's reference [39] (learnopencv
"Number of Parameters and Tensor Sizes in a CNN"):

* parameter memory  = #params * 4 bytes (f32)
* activation memory = #elements of the layer *output* tensor * 4 bytes
* ``M_client | l1``  = sum over layers 1..l1 of (param + activation) memory
* ``I | l1``         = activation bytes of layer l1 (what must be uploaded)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

DTYPE_BYTES = 4  # f32 end to end


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Conv2d:
    """Standard 2-D convolution (NCHW, OIHW weights), with bias."""

    in_ch: int
    out_ch: int
    kernel: int
    stride: int = 1
    padding: int = 0
    groups: int = 1
    bias: bool = True
    # Inference-time folded batch-norm: affine scale/shift applied to the
    # conv output. Parameters counted as 2*out_ch when present.
    folded_bn: bool = False

    @property
    def kind(self) -> str:
        return "conv2d"


@dataclass(frozen=True)
class ReLU:
    inplace: bool = True

    @property
    def kind(self) -> str:
        return "relu"


@dataclass(frozen=True)
class ReLU6:
    @property
    def kind(self) -> str:
        return "relu6"


@dataclass(frozen=True)
class MaxPool2d:
    kernel: int
    stride: int

    @property
    def kind(self) -> str:
        return "maxpool2d"


@dataclass(frozen=True)
class AdaptiveAvgPool2d:
    out_hw: int  # target H = W

    @property
    def kind(self) -> str:
        return "adaptiveavgpool2d"


@dataclass(frozen=True)
class Flatten:
    @property
    def kind(self) -> str:
        return "flatten"


@dataclass(frozen=True)
class Dropout:
    p: float = 0.5  # identity at inference; kept to preserve layer indices

    @property
    def kind(self) -> str:
        return "dropout"


@dataclass(frozen=True)
class Linear:
    """Fully-connected layer. torchvision applies ``torch.flatten`` (and for
    MobileNetV2, global average pooling) *functionally* inside ``forward``,
    so those ops are not separate modules and must not consume a layer
    index. A Linear therefore accepts 4-D input directly: with
    ``global_pool`` it mean-pools over H,W first (MobileNetV2), otherwise it
    flattens C*H*W (AlexNet/VGG)."""

    in_features: int
    out_features: int
    bias: bool = True
    global_pool: bool = False

    @property
    def kind(self) -> str:
        return "linear"


@dataclass(frozen=True)
class InvertedResidual:
    """MobileNetV2 inverted-residual block (counted as ONE layer, matching
    torchvision's ``features[i]`` granularity and the paper's 21-layer
    count). expand (1x1) → depthwise (3x3) → project (1x1), residual add
    when stride == 1 and in_ch == out_ch. BNs are folded."""

    in_ch: int
    out_ch: int
    stride: int
    expand_ratio: int

    @property
    def kind(self) -> str:
        return "inverted_residual"

    @property
    def hidden_ch(self) -> int:
        return self.in_ch * self.expand_ratio

    @property
    def use_residual(self) -> bool:
        return self.stride == 1 and self.in_ch == self.out_ch


LayerSpec = object  # union of the dataclasses above


@dataclass(frozen=True)
class ModelSpec:
    name: str
    layers: Tuple[LayerSpec, ...]
    input_hw: int = 224
    input_ch: int = 3
    num_classes: int = 1000
    # Published ImageNet top-1 accuracy (fraction). Used only for Fig. 10's
    # accuracy axis — a literature constant in the paper as well.
    top1_accuracy: float = 0.0

    @property
    def num_layers(self) -> int:
        return len(self.layers)


# ---------------------------------------------------------------------------
# Shape / parameter / FLOPs inference
# ---------------------------------------------------------------------------


def conv_out_hw(h: int, kernel: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - kernel) // stride + 1


def out_shape(layer: LayerSpec, in_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Output shape for a single layer. Shapes are (N, C, H, W) for conv
    stacks and (N, F) after Flatten."""
    if isinstance(layer, Conv2d):
        n, c, h, w = in_shape
        assert c == layer.in_ch, f"conv expects C={layer.in_ch}, got {c}"
        oh = conv_out_hw(h, layer.kernel, layer.stride, layer.padding)
        ow = conv_out_hw(w, layer.kernel, layer.stride, layer.padding)
        return (n, layer.out_ch, oh, ow)
    if isinstance(layer, (ReLU, ReLU6, Dropout)):
        return in_shape
    if isinstance(layer, MaxPool2d):
        n, c, h, w = in_shape
        oh = conv_out_hw(h, layer.kernel, layer.stride, 0)
        ow = conv_out_hw(w, layer.kernel, layer.stride, 0)
        return (n, c, oh, ow)
    if isinstance(layer, AdaptiveAvgPool2d):
        n, c, _, _ = in_shape
        return (n, c, layer.out_hw, layer.out_hw)
    if isinstance(layer, Flatten):
        n = in_shape[0]
        return (n, int(math.prod(in_shape[1:])))
    if isinstance(layer, Linear):
        n = in_shape[0]
        if len(in_shape) == 4 and layer.global_pool:
            f = in_shape[1]  # mean over H,W then flatten
        else:
            f = int(math.prod(in_shape[1:]))  # implicit flatten
        assert f == layer.in_features, f"linear expects F={layer.in_features}, got {f}"
        return (n, layer.out_features)
    if isinstance(layer, InvertedResidual):
        n, c, h, w = in_shape
        assert c == layer.in_ch
        oh = conv_out_hw(h, 3, layer.stride, 1)
        ow = conv_out_hw(w, 3, layer.stride, 1)
        return (n, layer.out_ch, oh, ow)
    raise TypeError(f"unknown layer spec {layer!r}")


def param_count(layer: LayerSpec) -> int:
    if isinstance(layer, Conv2d):
        per_group_in = layer.in_ch // layer.groups
        n = layer.out_ch * per_group_in * layer.kernel * layer.kernel
        if layer.bias:
            n += layer.out_ch
        if layer.folded_bn:
            n += 2 * layer.out_ch
        return n
    if isinstance(layer, Linear):
        n = layer.in_features * layer.out_features
        if layer.bias:
            n += layer.out_features
        return n
    if isinstance(layer, InvertedResidual):
        hid = layer.hidden_ch
        n = 0
        if layer.expand_ratio != 1:
            n += layer.in_ch * hid + 2 * hid  # 1x1 expand + folded BN
        n += hid * 9 + 2 * hid  # 3x3 depthwise + folded BN
        n += hid * layer.out_ch + 2 * layer.out_ch  # 1x1 project + folded BN
        return n
    return 0


def flop_count(layer: LayerSpec, in_shape: Tuple[int, ...]) -> int:
    """Multiply-accumulate-based FLOPs (2 * MACs) for the layer."""
    o = out_shape(layer, in_shape)
    if isinstance(layer, Conv2d):
        n, oc, oh, ow = o
        per_group_in = layer.in_ch // layer.groups
        macs = n * oc * oh * ow * per_group_in * layer.kernel * layer.kernel
        return 2 * macs
    if isinstance(layer, Linear):
        n = in_shape[0]
        flops = 2 * n * layer.in_features * layer.out_features
        if len(in_shape) == 4 and layer.global_pool:
            flops += int(math.prod(in_shape))  # global mean pool
        return flops
    if isinstance(layer, (ReLU, ReLU6)):
        return int(math.prod(in_shape))
    if isinstance(layer, MaxPool2d):
        n, c, oh, ow = o
        return n * c * oh * ow * layer.kernel * layer.kernel
    if isinstance(layer, AdaptiveAvgPool2d):
        return int(math.prod(in_shape))
    if isinstance(layer, InvertedResidual):
        n, c, h, w = in_shape
        hid = layer.hidden_ch
        _, oc, oh, ow = o
        macs = 0
        if layer.expand_ratio != 1:
            macs += n * h * w * layer.in_ch * hid  # 1x1 expand
        macs += n * oh * ow * hid * 9  # 3x3 depthwise
        macs += n * oh * ow * hid * oc  # 1x1 project
        flops = 2 * macs
        if layer.use_residual:
            flops += int(math.prod(o))
        return flops
    return 0


@dataclass(frozen=True)
class LayerInfo:
    """Everything the rust side needs to know about one layer."""

    index: int  # 1-based, matching the paper's split indices
    kind: str
    in_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    params: int
    param_bytes: int
    act_bytes: int  # output activation bytes == I|l when split after here
    flops: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(model: ModelSpec, batch: int = 1) -> List[LayerInfo]:
    """Walk the model, inferring shapes and derived quantities per layer."""
    infos: List[LayerInfo] = []
    shape: Tuple[int, ...] = (batch, model.input_ch, model.input_hw, model.input_hw)
    for i, layer in enumerate(model.layers):
        o = out_shape(layer, shape)
        p = param_count(layer)
        infos.append(
            LayerInfo(
                index=i + 1,
                kind=layer.kind,
                in_shape=shape,
                out_shape=o,
                params=p,
                param_bytes=p * DTYPE_BYTES,
                act_bytes=int(math.prod(o)) * DTYPE_BYTES,
                flops=flop_count(layer, shape),
            )
        )
        shape = o
    return infos


def client_memory_bytes(infos: Sequence[LayerInfo], l1: int) -> int:
    """``M_client | l1`` — params + activations of layers 1..l1 (paper §III-B1,
    ref [39])."""
    return sum(i.param_bytes + i.act_bytes for i in infos[:l1])


def intermediate_bytes(infos: Sequence[LayerInfo], l1: int) -> int:
    """``I | l1`` — bytes shipped to the cloud when splitting after layer l1."""
    return infos[l1 - 1].act_bytes


def server_memory_bytes(infos: Sequence[LayerInfo], l1: int) -> int:
    """``M_server | l2`` — params + activations of layers l1+1..L."""
    return sum(i.param_bytes + i.act_bytes for i in infos[l1:])


def total_params(model: ModelSpec) -> int:
    return sum(param_count(l) for l in model.layers)
