"""Pure-jnp/lax reference oracle for every L1 kernel.

These are the ground truth the Pallas kernels are pytest-checked against
(``python/tests/test_kernels.py``), and double as the ``--kernel-impl=ref``
AOT path used by the L1-vs-ref ablation bench.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def apply_act(x: jax.Array, act: str | None) -> jax.Array:
    if act is None:
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(f"unknown activation {act!r}")


def matmul_ref(x: jax.Array, w: jax.Array, bias=None, act: str | None = None) -> jax.Array:
    """(M,K) @ (K,N) with optional bias (N,) and activation fusion."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias[None, :]
    return apply_act(out, act)


def conv2d_ref(
    x: jax.Array,  # (N, C, H, W)
    w: jax.Array,  # (OC, C/groups, KH, KW)
    bias=None,  # (OC,)
    stride: int = 1,
    padding: int = 0,
    groups: int = 1,
    act: str | None = None,
    bn_scale=None,  # (OC,) folded batch-norm scale
    bn_shift=None,  # (OC,) folded batch-norm shift
) -> jax.Array:
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )
    if bias is not None:
        out = out + bias[None, :, None, None]
    if bn_scale is not None:
        out = out * bn_scale[None, :, None, None] + bn_shift[None, :, None, None]
    return apply_act(out, act)


def depthwise_conv_ref(
    x: jax.Array,  # (N, C, H, W)
    w: jax.Array,  # (C, 1, KH, KW)
    stride: int = 1,
    padding: int = 1,
    act: str | None = None,
    bn_scale=None,
    bn_shift=None,
) -> jax.Array:
    c = x.shape[1]
    return conv2d_ref(
        x, w, None, stride, padding, groups=c, act=act, bn_scale=bn_scale, bn_shift=bn_shift
    )


def maxpool2d_ref(x: jax.Array, kernel: int, stride: int) -> jax.Array:
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, kernel, kernel),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def adaptive_avgpool2d_ref(x: jax.Array, out_hw: int) -> jax.Array:
    """Matches torch AdaptiveAvgPool2d for the sizes in our zoo: each output
    cell averages the window [floor(i*H/O), ceil((i+1)*H/O))."""
    n, c, h, w = x.shape
    if h == out_hw and w == out_hw:
        return x
    rows = []
    for i in range(out_hw):
        h0, h1 = (i * h) // out_hw, -(-((i + 1) * h) // out_hw)
        cols = []
        for j in range(out_hw):
            w0, w1 = (j * w) // out_hw, -(-((j + 1) * w) // out_hw)
            cols.append(jnp.mean(x[:, :, h0:h1, w0:w1], axis=(2, 3)))
        rows.append(jnp.stack(cols, axis=-1))
    return jnp.stack(rows, axis=-2)


def linear_ref(x: jax.Array, w: jax.Array, bias=None, act: str | None = None,
               global_pool: bool = False) -> jax.Array:
    """(N, F) or (N,C,H,W) -> (N, out). 4-D input is globally mean-pooled
    (``global_pool``) or flattened, mirroring torchvision's functional ops."""
    if x.ndim == 4:
        x = jnp.mean(x, axis=(2, 3)) if global_pool else x.reshape(x.shape[0], -1)
    return matmul_ref(x, w, bias, act)
