"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracle.

Public surface:
  matmul_pallas, conv2d_pallas, depthwise_conv_pallas, maxpool2d_pallas
  ref.* — oracle used by pytest and the --kernel-impl=ref ablation.
"""

from . import ref  # noqa: F401
from .conv import conv2d_pallas, depthwise_conv_pallas  # noqa: F401
from .matmul import matmul_pallas, vmem_bytes  # noqa: F401
from .pool import maxpool2d_pallas  # noqa: F401
