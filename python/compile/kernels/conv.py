"""L1 conv kernels: im2col conv2d (over the Pallas matmul) and a dedicated
depthwise kernel for MobileNetV2.

The GPU-idiomatic formulation of conv is a threadblock-tiled implicit GEMM;
the TPU re-think (DESIGN.md §3) keeps the GEMM but makes the patch
extraction an XLA data-movement prologue (gather/reshape fuse into the
surrounding HLO) so that 100% of the MACs execute inside the MXU-tiled
Pallas matmul.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .matmul import matmul_pallas
from .ref import apply_act


def _im2col(x: jax.Array, kernel: int, stride: int, padding: int) -> jax.Array:
    """(N, C, H, W) -> (C*KH*KW, N*OH*OW) patch matrix."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    oh = (h + 2 * padding - kernel) // stride + 1
    ow = (w + 2 * padding - kernel) // stride + 1
    # One strided slice per (kh, kw) tap: kernel*kernel slices, each
    # (N, C, OH, OW). Static python loop => unrolled, fusable HLO.
    taps = []
    for kh in range(kernel):
        for kw in range(kernel):
            sl = lax.slice(
                xp,
                (0, 0, kh, kw),
                (n, c, kh + (oh - 1) * stride + 1, kw + (ow - 1) * stride + 1),
                (1, 1, stride, stride),
            )
            taps.append(sl)
    # (KH*KW, N, C, OH, OW) -> (C, KH*KW, N, OH, OW) -> (C*KH*KW, N*OH*OW)
    pat = jnp.stack(taps, axis=0).transpose(2, 0, 1, 3, 4)
    return pat.reshape(c * kernel * kernel, n * oh * ow), (n, oh, ow)


def conv2d_pallas(
    x: jax.Array,  # (N, C, H, W)
    w: jax.Array,  # (OC, C, KH, KW)
    bias: Optional[jax.Array] = None,
    stride: int = 1,
    padding: int = 0,
    act: Optional[str] = None,
    bn_scale: Optional[jax.Array] = None,
    bn_shift: Optional[jax.Array] = None,
    interpret: bool = True,
) -> jax.Array:
    """Standard conv as im2col + MXU matmul: out[oc, p] = W[oc, :] . pat[:, p]."""
    oc, c, kh, kw = w.shape
    assert kh == kw, "square kernels only in this zoo"
    pat, (n, oh, ow) = _im2col(x, kh, stride, padding)
    wmat = w.reshape(oc, c * kh * kw)
    # Fold inference batch-norm into the GEMM epilogue: scale rows of W and
    # fold shift into the bias so the fused epilogue handles everything.
    if bn_scale is not None:
        wmat = wmat * bn_scale[:, None]
        shift = bn_shift if bn_shift is not None else 0.0
        bias = shift if bias is None else bias * bn_scale + shift
    out = matmul_pallas(wmat, pat, None, None, interpret=interpret)  # (OC, N*OH*OW)
    if bias is not None:
        out = out + bias[:, None]
    out = apply_act(out, act)
    return out.reshape(oc, n, oh, ow).transpose(1, 0, 2, 3)


def _depthwise_kernel(x_ref, w_ref, o_ref, *, kernel: int, stride: int, act):
    """One block of channels. x block: (1, TC, HP, WP) pre-padded; w block:
    (TC, KH*KW); out block: (1, TC, OH, OW). Static tap loop -> vector FMAs."""
    x = x_ref[...]
    _, tc, hp, wp = x.shape
    _, oh, ow = o_ref.shape[1], o_ref.shape[2], o_ref.shape[3]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for t in range(kernel * kernel):
        dh, dw = divmod(t, kernel)
        sl = lax.slice(
            x,
            (0, 0, dh, dw),
            (1, tc, dh + (oh - 1) * stride + 1, dw + (ow - 1) * stride + 1),
            (1, 1, stride, stride),
        )
        acc = acc + sl * w_ref[:, t][None, :, None, None]
    o_ref[...] = apply_act(acc, act)


def depthwise_conv_pallas(
    x: jax.Array,  # (N, C, H, W)
    w: jax.Array,  # (C, 1, KH, KW)
    stride: int = 1,
    padding: int = 1,
    act: Optional[str] = None,
    bn_scale: Optional[jax.Array] = None,
    bn_shift: Optional[jax.Array] = None,
    *,
    tc: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Depthwise 3x3: one VMEM-resident channel block per grid step; the
    KH*KW tap loop is unrolled into vector FMAs (VPU work, no MXU)."""
    n, c, h, w_in = x.shape
    kh = w.shape[2]
    assert n == 1 or True
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w_in + 2 * padding - kh) // stride + 1

    wmat = w.reshape(c, kh * kh)
    shift = None
    if bn_scale is not None:
        wmat = wmat * bn_scale[:, None]
        shift = bn_shift

    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    tc = min(tc, c)
    cp = (c + tc - 1) // tc * tc
    xp = jnp.pad(xp, ((0, 0), (0, cp - c), (0, 0), (0, 0)))
    wp = jnp.pad(wmat, ((0, cp - c), (0, 0)))
    hp, wpad = xp.shape[2], xp.shape[3]

    grid = (xp.shape[0], cp // tc)
    out = pl.pallas_call(
        lambda x_ref, w_ref, o_ref: _depthwise_kernel(
            x_ref, w_ref, o_ref, kernel=kh, stride=stride, act=None
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, hp, wpad), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((tc, kh * kh), lambda b, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tc, oh, ow), lambda b, j: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], cp, oh, ow), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    out = out[:, :c]
    if shift is not None:
        out = out + shift[None, :, None, None]
    return apply_act(out, act)
