"""L1 pooling kernel: max-pool as a Pallas kernel with an unrolled tap loop.

Pooling is bandwidth-bound, so the only thing that matters is touching each
input element once while it is VMEM-resident: the grid walks channel blocks
of the (N, C, H, W) input and the KxK tap loop runs as vector max ops over
strided slices of the resident block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, kernel: int, stride: int):
    x = x_ref[...]
    _, tc, hp, wp = x.shape
    oh, ow = o_ref.shape[2], o_ref.shape[3]
    acc = jnp.full(o_ref.shape, -jnp.inf, jnp.float32)
    for t in range(kernel * kernel):
        dh, dw = divmod(t, kernel)
        sl = lax.slice(
            x,
            (0, 0, dh, dw),
            (1, tc, dh + (oh - 1) * stride + 1, dw + (ow - 1) * stride + 1),
            (1, 1, stride, stride),
        )
        acc = jnp.maximum(acc, sl)
    o_ref[...] = acc


def maxpool2d_pallas(
    x: jax.Array,  # (N, C, H, W)
    kernel: int,
    stride: int,
    *,
    tc: int = 64,
    interpret: bool = True,
) -> jax.Array:
    n, c, h, w = x.shape
    oh = (h - kernel) // stride + 1
    ow = (w - kernel) // stride + 1
    tc = min(tc, c)
    cp = (c + tc - 1) // tc * tc
    # Pad channels with -inf-safe zeros (sliced off below) to a tile multiple.
    xp = jnp.pad(x, ((0, 0), (0, cp - c), (0, 0), (0, 0)))
    grid = (n, cp // tc)
    out = pl.pallas_call(
        lambda x_ref, o_ref: _maxpool_kernel(x_ref, o_ref, kernel=kernel, stride=stride),
        grid=grid,
        in_specs=[pl.BlockSpec((1, tc, h, w), lambda b, j: (b, j, 0, 0))],
        out_specs=pl.BlockSpec((1, tc, oh, ow), lambda b, j: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cp, oh, ow), jnp.float32),
        interpret=interpret,
    )(xp)
    return out[:, :c]
