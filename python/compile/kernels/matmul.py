"""L1 Pallas kernel: MXU-tiled matmul with fused bias + activation.

This is the single compute hot-spot of the whole stack: conv layers are
lowered to it via im2col (``conv.py``) and linear layers call it directly,
so every MAC in every CNN of the zoo flows through this kernel.

TPU thinking (see DESIGN.md §3 Hardware-Adaptation):

* the grid is (M/TM, N/TN, K/TK); each (i, j) output tile is accumulated
  over the K axis — the BlockSpec expresses the HBM->VMEM schedule that a
  GPU implementation would express with threadblocks + shared-memory
  staging;
* default tiles TM=TN=128, TK=512 keep the VMEM working set at
  TM*TK + TK*TN + TM*TN = 147k f32 = 0.56 MiB, leaving double-buffering
  headroom way under the 16 MiB VMEM budget while feeding the 128x128 MXU
  systolic array full-width tiles;
* bias add + activation are fused into the final K step so the output tile
  is written exactly once.

Lowered with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls; on a real TPU the same kernel lowers natively (§Perf records
the estimated MXU utilisation).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import apply_act

# Default MXU-shaped tiles.
TM_DEFAULT = 128
TN_DEFAULT = 128
TK_DEFAULT = 512

# Tile profiles (§Perf L1, DESIGN.md §Hardware-Adaptation):
#
# * "tpu" — VMEM-faithful schedule: one grid step's working set stays under
#   half of a 16 MiB VMEM (double-buffer headroom). This is the BlockSpec a
#   real TPU lowering would use; the §Perf MXU/VMEM estimates use it.
# * "cpu" — execution profile for the interpret-mode artifacts the CPU PJRT
#   client runs. Interpret lowering pays a per-grid-step cost proportional
#   to the bytes it dynamic-slices, so the optimum is the *fewest* grid
#   steps: single-block whenever the operands fit a generous host budget.
#   (Measured on AlexNet fc1: 32-step K-grid 32.4 s → single block 21 ms.)
#
# The AOT driver selects the profile (`--tile-profile`, default cpu).

VMEM_BUDGET_WORDS = (8 * 1024 * 1024) // 4
CPU_BUDGET_WORDS = 64 * 1024 * 1024  # 256 MiB working set cap


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m

_TILE_PROFILE = "cpu"


def set_tile_profile(profile: str) -> None:
    """Select the tiling profile: "cpu" (default) or "tpu"."""
    global _TILE_PROFILE
    assert profile in ("cpu", "tpu"), profile
    _TILE_PROFILE = profile


def get_tile_profile() -> str:
    return _TILE_PROFILE


def pick_tiles(m: int, k: int, n: int, profile: str = None) -> tuple:
    """Choose (tm, tn, tk) for an (M,K)x(K,N) matmul under the profile."""
    profile = profile or _TILE_PROFILE
    if profile == "cpu":
        # Minimise grid steps: full M and K, widest N that fits the budget.
        tm = _round_up(m, 8)
        tk = _round_up(k, 8)
        tn_cap = max(128, (CPU_BUDGET_WORDS - tm * tk) // max(1, tk + tm))
        tn = min(_round_up(n, 8), _round_up(tn_cap, 8))
        return tm, tn, tk
    # "tpu": MXU-width output tiles, K streamed up to the VMEM budget.
    tm = min(TM_DEFAULT, _round_up(m, 8))
    tn = min(TN_DEFAULT, _round_up(n, 8))
    tk_budget = max(TK_DEFAULT, (VMEM_BUDGET_WORDS - tm * tn) // (tm + tn))
    tk = min(_round_up(k, 8), _round_up(tk_budget, 8))
    return tm, tn, tk


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: Optional[str], bias: bool):
    """One (TM, TN) output tile; grid axis 2 streams K in TK chunks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...]
        if bias:
            out = out + b_ref[...]
        o_ref[...] = apply_act(out, act)


def matmul_pallas(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    bias: Optional[jax.Array] = None,  # (N,)
    act: Optional[str] = None,
    *,
    tm: int = 0,
    tn: int = 0,
    tk: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Tiled ``x @ w (+ bias) (act)`` -> (M, N) f32. Tiles default to
    ``pick_tiles``; explicit values are clamped to the padded problem."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    auto_tm, auto_tn, auto_tk = pick_tiles(m, k, n)
    tm = auto_tm if tm <= 0 else min(tm, _round_up(m, 8))
    tn = auto_tn if tn <= 0 else min(tn, _round_up(n, 8))
    tk = auto_tk if tk <= 0 else min(tk, _round_up(k, 8))
    mp, kp, np_ = _round_up(m, tm), _round_up(k, tk), _round_up(n, tn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    has_bias = bias is not None
    bp = jnp.pad(bias, (0, np_ - n)) if has_bias else jnp.zeros((np_,), x.dtype)
    bp = bp.reshape(1, np_)

    grid = (mp // tm, np_ // tn, kp // tk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2], act=act, bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(tm: int = TM_DEFAULT, tn: int = TN_DEFAULT, tk: int = TK_DEFAULT) -> int:
    """Estimated VMEM working set of one grid step (f32), used by the §Perf
    roofline accounting."""
    return 4 * (tm * tk + tk * tn + tm * tn + tn)
