"""AOT driver: lower every layer of every zoo model to HLO text + weight
binaries + a JSON manifest consumed by the rust runtime.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact layout (per model)::

    artifacts/<model>/manifest.json
    artifacts/<model>/weights/layer_NNN_<name>.bin      raw little-endian f32
    artifacts/<model>/b<B>/layer_NNN.hlo.txt            one HLO per layer

Each layer HLO computes ``fn(activation, *weights) -> (activation,)``
(tuple-returned). Weights are HLO *parameters* so the rust runtime uploads
them once as PJRT literals and reuses them across requests; embedding
VGG16's 138M parameters as HLO text constants would produce multi-GB
artifacts.

Usage::

    python -m compile.aot --out-dir ../artifacts \
        --models alexnet:1,8 vgg11 vgg13 vgg16 mobilenet_v2:1,8
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as mdl
from . import specs, zoo


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    ``return_tuple=False``: every layer has exactly one output, and a bare
    array result lets the rust runtime chain layer executions entirely in
    PJRT device buffers (``execute_b``) without the host round-trip a tuple
    result would force. (§Perf: buffer-chaining vs literal path.)"""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_layer(layer, in_shape: Tuple[int, ...], params: mdl.Params,
                impl: str = "pallas") -> str:
    """Lower one layer to HLO text, with activation + weights as params."""
    fn = mdl.layer_fn(layer, impl)
    x_spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32)
               for _, a in mdl.flat_weights(layer, params)]
    lowered = jax.jit(fn).lower(x_spec, *w_specs)
    return to_hlo_text(lowered)


def build_model_artifacts(
    model: specs.ModelSpec,
    out_dir: str,
    batches: Sequence[int] = (1,),
    impl: str = "pallas",
    seed: int = 0,
    verbose: bool = True,
) -> Dict:
    """Emit all artifacts for one model; returns the manifest dict."""
    mdir = os.path.join(out_dir, model.name)
    wdir = os.path.join(mdir, "weights")
    os.makedirs(wdir, exist_ok=True)
    params = mdl.init_model_params(model, seed)
    infos = specs.analyze(model, batch=1)

    manifest: Dict = {
        "model": model.name,
        "impl": impl,
        "seed": seed,
        "num_layers": model.num_layers,
        "paper_layers": zoo.PAPER_LAYERS[model.name],
        "input_hw": model.input_hw,
        "input_ch": model.input_ch,
        "num_classes": model.num_classes,
        "top1_accuracy": model.top1_accuracy,
        "total_params": specs.total_params(model),
        "batches": list(batches),
        "layers": [],
    }

    # Weights (batch-independent).
    weight_meta: List[List[Dict]] = []
    for i, (layer, p) in enumerate(zip(model.layers, params)):
        metas = []
        for name, arr in mdl.flat_weights(layer, p):
            fname = f"layer_{i + 1:03d}_{name}.bin"
            arr.astype("<f4").tofile(os.path.join(wdir, fname))
            metas.append({"name": name, "file": f"weights/{fname}",
                          "shape": list(arr.shape)})
        weight_meta.append(metas)

    # Per-layer HLO, per batch size.
    hlo_paths: List[Dict[str, str]] = [dict() for _ in model.layers]
    for b in batches:
        bdir = os.path.join(mdir, f"b{b}")
        os.makedirs(bdir, exist_ok=True)
        binfos = specs.analyze(model, batch=b)
        for i, (layer, p, info) in enumerate(zip(model.layers, params, binfos)):
            text = lower_layer(layer, info.in_shape, p, impl)
            rel = f"b{b}/layer_{i + 1:03d}.hlo.txt"
            with open(os.path.join(mdir, rel), "w") as f:
                f.write(text)
            hlo_paths[i][str(b)] = rel
            if verbose:
                print(f"  [{model.name} b{b}] layer {i + 1:3d}/{model.num_layers} "
                      f"{layer.kind:<18} {info.in_shape} -> {info.out_shape} "
                      f"({len(text) / 1024:.0f} KiB hlo)", flush=True)

    for i, (layer, info) in enumerate(zip(model.layers, infos)):
        manifest["layers"].append({
            "index": info.index,
            "kind": info.kind,
            "in_shape": list(info.in_shape),
            "out_shape": list(info.out_shape),
            "params": info.params,
            "param_bytes": info.param_bytes,
            "act_bytes": info.act_bytes,
            "flops": info.flops,
            "weights": weight_meta[i],
            "hlo": hlo_paths[i],
        })

    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def parse_model_arg(arg: str) -> Tuple[str, List[int]]:
    """``vgg11`` -> ("vgg11", [1]);  ``alexnet:1,8`` -> ("alexnet", [1, 8])."""
    if ":" in arg:
        name, bs = arg.split(":", 1)
        return name, [int(x) for x in bs.split(",")]
    return arg, [1]


DEFAULT_MODELS = ["alexnet:1,8", "vgg11", "vgg13", "vgg16", "mobilenet_v2:1,8"]


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=DEFAULT_MODELS,
                    help="model[:batch,batch...] entries")
    ap.add_argument("--kernel-impl", choices=["pallas", "ref"], default="pallas")
    ap.add_argument("--tile-profile", choices=["cpu", "tpu"], default="cpu",
                    help="L1 matmul tiling: cpu = fewest grid steps for the "
                         "interpret/CPU artifacts; tpu = VMEM-faithful BlockSpec")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from .kernels.matmul import set_tile_profile
    set_tile_profile(args.tile_profile)
    for entry in args.models:
        name, batches = parse_model_arg(entry)
        model = zoo.ZOO[name]()
        print(f"== {name}: {model.num_layers} layers, batches {batches}, "
              f"impl={args.kernel_impl}", flush=True)
        build_model_artifacts(model, args.out_dir, batches,
                              args.kernel_impl, args.seed,
                              verbose=not args.quiet)
    # Build stamp lets `make` skip regeneration when inputs are unchanged.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
