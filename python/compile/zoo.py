"""The CNN zoo used by the paper: AlexNet (21 layers), VGG11 (29), VGG13
(33), VGG16 (39) and MobileNetV2 (21).

Layer sequences mirror torchvision's flattened
``features → avgpool → classifier`` module lists exactly — that is the
granularity at which the paper counts split indices. Dropout layers are
inference-time identities but are kept so indices line up.

Top-1 accuracies are the published torchvision ImageNet numbers; they feed
only Fig. 10's accuracy axis (the paper likewise reports literature
accuracy, not re-trained accuracy).
"""

from __future__ import annotations

from typing import List

from .specs import (
    AdaptiveAvgPool2d,
    Conv2d,
    Dropout,
    Flatten,
    InvertedResidual,
    Linear,
    MaxPool2d,
    ModelSpec,
    ReLU,
    ReLU6,
)


def alexnet(num_classes: int = 1000) -> ModelSpec:
    """AlexNet — 13 feature modules + avgpool + flatten-free classifier of 7
    modules = 21 layers. (torchvision inserts the flatten as a functional
    op, so the paper's count of 21 holds; we fold the flatten into the
    first Linear's input and model avgpool as AdaptiveAvgPool2d(6).)"""
    layers = (
        Conv2d(3, 64, kernel=11, stride=4, padding=2),
        ReLU(),
        MaxPool2d(kernel=3, stride=2),
        Conv2d(64, 192, kernel=5, padding=2),
        ReLU(),
        MaxPool2d(kernel=3, stride=2),
        Conv2d(192, 384, kernel=3, padding=1),
        ReLU(),
        Conv2d(384, 256, kernel=3, padding=1),
        ReLU(),
        Conv2d(256, 256, kernel=3, padding=1),
        ReLU(),
        MaxPool2d(kernel=3, stride=2),
        AdaptiveAvgPool2d(6),
        Dropout(),
        Linear(256 * 6 * 6, 4096),
        ReLU(),
        Dropout(),
        Linear(4096, 4096),
        ReLU(),
        Linear(4096, num_classes),
    )
    return ModelSpec("alexnet", layers, top1_accuracy=0.5652)


def _vgg(name: str, cfg: List, num_classes: int, top1: float) -> ModelSpec:
    layers: List = []
    in_ch = 3
    for v in cfg:
        if v == "M":
            layers.append(MaxPool2d(kernel=2, stride=2))
        else:
            layers.append(Conv2d(in_ch, v, kernel=3, padding=1))
            layers.append(ReLU())
            in_ch = v
    layers.append(AdaptiveAvgPool2d(7))
    layers += [
        Dropout(),
        Linear(512 * 7 * 7, 4096),
        ReLU(),
        Dropout(),
        Linear(4096, 4096),
        ReLU(),
        Linear(4096, num_classes),
    ]
    return ModelSpec(name, tuple(layers), top1_accuracy=top1)


def vgg11(num_classes: int = 1000) -> ModelSpec:
    """VGG11 — 21 feature modules + avgpool + 7 classifier modules = 29."""
    cfg = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]
    return _vgg("vgg11", cfg, num_classes, top1=0.6902)


def vgg13(num_classes: int = 1000) -> ModelSpec:
    """VGG13 — 25 feature modules + avgpool + 7 classifier modules = 33."""
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]
    return _vgg("vgg13", cfg, num_classes, top1=0.6992)


def vgg16(num_classes: int = 1000) -> ModelSpec:
    """VGG16 — 31 feature modules + avgpool + 7 classifier modules = 39."""
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    return _vgg("vgg16", cfg, num_classes, top1=0.7159)


def mobilenet_v2(num_classes: int = 1000) -> ModelSpec:
    """MobileNetV2 — 19 feature blocks + avgpool-equivalent + classifier =
    21 layers at torchvision ``features[i]`` granularity: stem conv,
    17 inverted-residual blocks, head conv, then (pool+flatten folded)
    dropout + linear."""
    # (expand_ratio t, out channels c, repeats n, first stride s)
    inverted_cfg = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ]
    layers: List = [Conv2d(3, 32, kernel=3, stride=2, padding=1, bias=False, folded_bn=True)]
    in_ch = 32
    for t, c, n, s in inverted_cfg:
        for i in range(n):
            layers.append(InvertedResidual(in_ch, c, stride=s if i == 0 else 1, expand_ratio=t))
            in_ch = c
    layers.append(Conv2d(in_ch, 1280, kernel=1, bias=False, folded_bn=True))  # head
    # torchvision applies global avg-pool + flatten functionally; they are
    # not modules and don't consume layer indices (paper count: 21).
    layers.append(Dropout(0.2))
    layers.append(Linear(1280, num_classes, global_pool=True))
    return ModelSpec("mobilenet_v2", tuple(layers), top1_accuracy=0.7188)


ZOO = {
    "alexnet": alexnet,
    "vgg11": vgg11,
    "vgg13": vgg13,
    "vgg16": vgg16,
    "mobilenet_v2": mobilenet_v2,
}

# Paper layer counts (§VI-A); each must equal ModelSpec.num_layers.
PAPER_LAYERS = {
    "alexnet": 21,
    "vgg11": 29,
    "vgg13": 33,
    "vgg16": 39,
    "mobilenet_v2": 21,
}
